//! Multi-threaded quantization execution engine.
//!
//! The independent-blocks structure of Eq. 6 makes every quantization
//! group — one `(zero-point, range)` pair plus its slice of codes —
//! embarrassingly parallel, which is exactly what ActNN and GACT exploit
//! for throughput. [`QuantEngine`] shards the flat block list of
//! [`BlockwiseQuantizer`](crate::quant::BlockwiseQuantizer) (and the
//! per-row groups of [`RowQuantizer`](crate::quant::RowQuantizer)) into
//! contiguous per-thread shards executed on a persistent
//! [`WorkerPool`](crate::runtime::pool::WorkerPool) — threads are
//! spawned once per engine, not once per call, and the same pool is the
//! substrate for the tiled dense/sparse kernels (see `docs/runtime.md`).
//!
//! The codec itself is word-parallel and fusion-first (see
//! `docs/codec.md`): quantization stochastically rounds **straight into
//! packed bytes** ([`crate::quant`]'s `quantize_pack_block`) whenever
//! blocks occupy whole bytes — always true for heterogeneous
//! [`BitPlan`]s and for any fixed-width layout with
//! `group_len · bits ≡ 0 (mod 8)` — and dequantization decodes packed
//! bytes **directly to `f32`** through per-block value LUTs. Neither
//! side materializes an intermediate `u8` code buffer, so the
//! [`BufferPool`]'s only codec byte traffic is the packed output itself
//! (observable via [`PoolStats::max_byte_take`](crate::memory::PoolStats)).
//!
//! Beyond plain quantize/dequantize, the engine owns the **fused
//! dequantize→aggregate** kernels of the backward hot path:
//! [`QuantEngine::dequantize_matmul_planned`] /
//! [`QuantEngine::dequantize_matmul`] stream each decoded block straight
//! into a matmul consumer (the `IRP` recovery), and
//! [`QuantEngine::dequantize_spmm_planned`] streams decoded row tiles
//! into a CSR aggregation — neither materializes the full dense
//! dequantized matrix (scratch is one block per worker, recycled through
//! the [`BufferPool`]).
//!
//! ## Determinism
//!
//! Block `g` always draws its stochastic-rounding randomness from the
//! deterministic stream [`Pcg64::with_stream`]`(seed, g)` — the stream
//! assignment depends only on the block *index*, never on which worker
//! processes it or how many workers exist. Parallel output is therefore
//! **bit-identical to serial** for the same seed, at every bit width and
//! any thread count:
//!
//! ```
//! use iexact::engine::QuantEngine;
//! use iexact::quant::BinSpec;
//! use iexact::rngs::Pcg64;
//! use iexact::tensor::Matrix;
//!
//! let mut rng = Pcg64::new(7);
//! let h = Matrix::from_fn(64, 32, |_, _| rng.next_f32());
//! let serial = QuantEngine::serial()
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! let parallel = QuantEngine::with_threads(4)
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! assert_eq!(serial.packed, parallel.packed);
//! assert_eq!(serial.zeros, parallel.zeros);
//! ```
//!
//! ## Configuration
//!
//! Production code builds the engine from the `[parallelism]` config
//! section via [`QuantEngine::from_config`]; see
//! [`ParallelismConfig`](crate::config::ParallelismConfig) for the
//! thread-count and shard-granularity knobs and the auto heuristic.

use crate::alloc::{BitPlan, PlannedTensor};
use crate::config::ParallelismConfig;
use crate::graph::CsrMatrix;
use crate::memory::BufferPool;
use crate::quant::{
    pack_codes_slice_isa, quantize_block, quantize_pack_block, unpack_dequantize_block_tiled,
    BinSpec, CodecIsa, CompressedTensor, DequantPlan, QuantPlan,
};
use crate::rngs::Pcg64;
use crate::runtime::pool::{Task, WorkerPool, MIN_ROWS_PER_SHARD};
use crate::tensor::{row_axpy_matmul, Matrix};
use crate::{Error, Result};
use std::sync::Arc;

/// Auto-mode worker-count cap, re-exported from the shared pool so
/// existing references keep working.
pub use crate::runtime::pool::MAX_AUTO_THREADS;

/// Slot in a per-width lookup array for the supported widths 1/2/4/8
/// (1 → 0, 2 → 1, 4 → 2, 8 → 3).
#[inline]
fn width_slot(bits: u32) -> usize {
    bits.trailing_zeros() as usize
}

/// Validate a [`CompressedTensor`]'s width, layout and metadata — the
/// single checkpoint shared by the fixed-width entry points (dequantize
/// and fused matmul), so a format invariant added here holds for both.
fn validate_compressed(ct: &CompressedTensor) -> Result<()> {
    if !matches!(ct.bits, 1 | 2 | 4 | 8) {
        return Err(Error::Config(format!("unsupported bit width {}", ct.bits)));
    }
    if ct.group_len == 0 {
        return Err(Error::Config("group_len must be positive".into()));
    }
    let (rows, cols) = ct.shape;
    let n = rows * cols;
    let num_groups = n.div_ceil(ct.group_len);
    let codes_per_byte = (8 / ct.bits) as usize;
    if ct.packed.len() * codes_per_byte < n {
        return Err(Error::Shape(format!(
            "packed buffer too short: wanted {n} codes, got {}",
            ct.packed.len() * codes_per_byte
        )));
    }
    if ct.zeros.len() != num_groups || ct.ranges.len() != num_groups {
        return Err(Error::Shape(format!(
            "expected {num_groups} (zero, range) pairs, got ({}, {})",
            ct.zeros.len(),
            ct.ranges.len()
        )));
    }
    Ok(())
}

/// Validate a [`PlannedTensor`]'s packed layout and metadata, returning
/// its per-block byte offsets. The single checkpoint shared by every
/// planned entry point (dequantize, fused matmul, fused spmm), so a
/// format invariant added here holds for all of them at once.
fn validate_planned(pt: &PlannedTensor) -> Result<Vec<usize>> {
    let (rows, cols) = pt.shape;
    let n = rows * cols;
    let num_groups = pt.plan.num_blocks();
    let offsets = pt.plan.offsets(n)?;
    let total_bytes = *offsets.last().expect("offsets non-empty");
    if pt.packed.len() < total_bytes {
        return Err(Error::Shape(format!(
            "packed buffer too short: plan needs {total_bytes} bytes, got {}",
            pt.packed.len()
        )));
    }
    if pt.zeros.len() != num_groups || pt.ranges.len() != num_groups {
        return Err(Error::Shape(format!(
            "expected {num_groups} (zero, range) pairs, got ({}, {})",
            pt.zeros.len(),
            pt.ranges.len()
        )));
    }
    Ok(offsets)
}

/// Sharded executor for grouped quantize/dequantize.
///
/// The engine runs on a persistent
/// [`WorkerPool`](crate::runtime::pool::WorkerPool): threads are spawned
/// once at construction and reused by every call, so per-layer fan-out
/// costs a channel send instead of an OS thread spawn. Cloning is cheap
/// (the pool is shared through an `Arc`), so the engine can be passed
/// freely across the pipeline, coordinator and benches. The tiled dense
/// and sparse kernels accept the same pool via
/// [`QuantEngine::runtime`], making one config-sized pool the execution
/// substrate for the whole training step.
#[derive(Debug, Clone)]
pub struct QuantEngine {
    pool: Arc<WorkerPool>,
    min_blocks_per_shard: usize,
    codec_isa: CodecIsa,
}

impl PartialEq for QuantEngine {
    fn eq(&self, other: &Self) -> bool {
        self.threads() == other.threads()
            && self.min_blocks_per_shard == other.min_blocks_per_shard
            && self.codec_isa == other.codec_isa
    }
}

impl Eq for QuantEngine {}

impl QuantEngine {
    /// Single-threaded engine — the reference every parallel result is
    /// bit-compared against.
    pub fn serial() -> Self {
        QuantEngine {
            pool: Arc::new(WorkerPool::serial()),
            min_blocks_per_shard: 1,
            codec_isa: CodecIsa::active(),
        }
    }

    /// Engine with an explicit worker count (`0` = auto-detect). Shard
    /// gating is disabled (`min_blocks_per_shard = 1`) so even small
    /// inputs fan out — the right default for tests and benches;
    /// production configs go through [`Self::from_config`].
    pub fn with_threads(threads: usize) -> Self {
        QuantEngine {
            pool: Arc::new(WorkerPool::new(threads)),
            min_blocks_per_shard: 1,
            codec_isa: CodecIsa::active(),
        }
    }

    /// Engine for the default [`ParallelismConfig`]: auto thread count,
    /// production shard gating.
    pub fn auto() -> Self {
        Self::from_config(&ParallelismConfig::default())
    }

    /// Build from the `[parallelism]` config section, resolving auto mode
    /// against `std::thread::available_parallelism` and the codec ISA
    /// against `IEXACT_CODEC_ISA` / `parallelism.codec_isa` / feature
    /// detection (in that precedence order).
    pub fn from_config(cfg: &ParallelismConfig) -> Self {
        QuantEngine {
            pool: Arc::new(WorkerPool::from_config(cfg)),
            min_blocks_per_shard: cfg.min_blocks_per_shard.max(1),
            codec_isa: cfg.resolved_codec_isa(),
        }
    }

    /// Engine on an existing shared pool (one pool, many consumers).
    pub fn with_runtime(pool: Arc<WorkerPool>, min_blocks_per_shard: usize) -> Self {
        QuantEngine {
            pool,
            min_blocks_per_shard: min_blocks_per_shard.max(1),
            codec_isa: CodecIsa::active(),
        }
    }

    /// Pin this engine's codec kernels to one ISA tier, bypassing the
    /// detected default — the forcing knob the dispatch test matrix and
    /// per-ISA bench arms are built on. Errors if `isa` is not runnable
    /// on this CPU (forcing must fail loud, never silently fall back).
    pub fn with_codec_isa(mut self, isa: CodecIsa) -> Result<Self> {
        if !isa.is_available() {
            return Err(Error::Config(format!(
                "codec ISA '{isa}' is not available on this CPU (available: {})",
                CodecIsa::available()
                    .iter()
                    .map(|i| i.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )));
        }
        self.codec_isa = isa;
        Ok(self)
    }

    /// The codec ISA tier this engine's pack/unpack/dequantize kernels run on.
    pub fn codec_isa(&self) -> CodecIsa {
        self.codec_isa
    }

    /// The shared compute runtime this engine executes on — pass it to
    /// [`Matrix::matmul_with`](crate::tensor::Matrix::matmul_with) /
    /// [`CsrMatrix::spmm_with`](crate::graph::CsrMatrix::spmm_with) so
    /// the dense and sparse kernels share the engine's workers.
    pub fn runtime(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Resolved worker-count ceiling for this engine.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Worker count actually used for `num_blocks` independent blocks:
    /// stays serial until at least two shards of `min_blocks_per_shard`
    /// blocks exist (fan-out below that loses more to scheduling overhead
    /// than it gains), then grows linearly and caps at the configured
    /// thread count.
    pub fn effective_shards(&self, num_blocks: usize) -> usize {
        self.pool.shards_for(num_blocks, self.min_blocks_per_shard)
    }

    /// Grouped quantization (Eq. 2 + Eq. 6) with randomness drawn from
    /// `rng`: one `u64` draw keys the per-block streams, so the caller's
    /// generator advances identically regardless of thread count.
    pub fn quantize(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
    ) -> Result<CompressedTensor> {
        self.quantize_seeded(h, group_len, bits, bins, rng.next_u64())
    }

    /// Seed-addressed grouped quantization. Bit-identical across engines:
    /// `serial().quantize_seeded(..)` ==
    /// `with_threads(n).quantize_seeded(..)` for every `n`.
    pub fn quantize_seeded(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, seed, None)
    }

    /// [`Self::quantize`] with scratch and output buffers recycled
    /// through `pool` — the packed buffer comes from the pool and the
    /// code scratch returns to it, so steady-state training does no
    /// per-layer allocation for the compressed path.
    pub fn quantize_pooled(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
        pool: &mut BufferPool,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, rng.next_u64(), Some(pool))
    }

    fn quantize_impl(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<CompressedTensor> {
        let plan = QuantPlan::resolve(bits, bins, group_len)?;
        let data = h.as_slice();
        let n = data.len();
        let num_groups = n.div_ceil(group_len);
        let total_bytes = (n * bits as usize).div_ceil(8);
        let mut zeros = vec![0f32; num_groups];
        let mut ranges = vec![0f32; num_groups];

        // Fused path: when a full block's bit count is a whole number of
        // bytes (every production group length — G is a multiple of the
        // projected width), each block owns a disjoint byte range of the
        // packed stream and stochastic rounding writes straight into it
        // via `quantize_pack_block`. No n-byte code scratch exists on
        // either the serial or the parallel path, and shard byte ranges
        // stay disjoint so workers never share a byte.
        if (group_len * bits as usize) % 8 == 0 {
            // Every byte of `packed` is written below (partial final
            // bytes zero-padded), so an unspecified-content take is safe.
            let mut packed = match pool.as_deref_mut() {
                Some(p) => p.take_bytes_scratch(total_bytes),
                None => vec![0u8; total_bytes],
            };
            let block_bytes = group_len * bits as usize / 8;
            let shards = self.effective_shards(num_groups);
            if shards <= 1 {
                for g in 0..num_groups {
                    let start = g * group_len;
                    let end = (start + group_len).min(n);
                    let byte_lo = g * block_bytes;
                    let byte_hi = byte_lo + ((end - start) * bits as usize).div_ceil(8);
                    let mut rng_g = Pcg64::with_stream(seed, g as u64);
                    let (z, r) = quantize_pack_block(
                        &plan,
                        &data[start..end],
                        &mut packed[byte_lo..byte_hi],
                        &mut rng_g,
                    );
                    zeros[g] = z;
                    ranges[g] = r;
                }
            } else {
                let groups_per_shard = num_groups.div_ceil(shards);
                let chunk = groups_per_shard * group_len;
                let chunk_bytes = groups_per_shard * block_bytes;
                let plan = &plan;
                let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shards);
                for (idx, (((data_c, packed_c), zeros_c), ranges_c)) in data
                    .chunks(chunk)
                    .zip(packed.chunks_mut(chunk_bytes))
                    .zip(zeros.chunks_mut(groups_per_shard))
                    .zip(ranges.chunks_mut(groups_per_shard))
                    .enumerate()
                {
                    let base = idx * groups_per_shard;
                    tasks.push(Box::new(move || {
                        for (j, (z, r)) in
                            zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                        {
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(data_c.len());
                            let byte_lo = j * block_bytes;
                            let byte_hi = byte_lo + ((hi - lo) * bits as usize).div_ceil(8);
                            let mut rng_g = Pcg64::with_stream(seed, (base + j) as u64);
                            let (zz, rr) = quantize_pack_block(
                                plan,
                                &data_c[lo..hi],
                                &mut packed_c[byte_lo..byte_hi],
                                &mut rng_g,
                            );
                            *z = zz;
                            *r = rr;
                        }
                    }));
                }
                self.pool.run(tasks);
            }
            return Ok(CompressedTensor {
                packed,
                zeros,
                ranges,
                shape: h.shape(),
                group_len,
                bits,
                bins: bins.clone(),
            });
        }

        // Two-pass fallback for group boundaries that land mid-byte
        // (possible only when `group_len * bits % 8 != 0`): SR into a
        // code scratch, then one global pack. Bit-identical to the fused
        // path by the shared SR core; proven by `tests/codec_fusion.rs`.
        let mut codes = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_scratch(n),
            None => vec![0u8; n],
        };
        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                let mut rng_g = Pcg64::with_stream(seed, g as u64);
                let (z, r) =
                    quantize_block(&plan, &data[start..end], &mut codes[start..end], &mut rng_g);
                zeros[g] = z;
                ranges[g] = r;
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let plan = &plan;
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shards);
            for (idx, (((data_c, codes_c), zeros_c), ranges_c)) in data
                .chunks(chunk)
                .zip(codes.chunks_mut(chunk))
                .zip(zeros.chunks_mut(groups_per_shard))
                .zip(ranges.chunks_mut(groups_per_shard))
                .enumerate()
            {
                let base = idx * groups_per_shard;
                tasks.push(Box::new(move || {
                    for (j, (z, r)) in
                        zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                    {
                        let lo = j * group_len;
                        let hi = (lo + group_len).min(data_c.len());
                        let mut rng_g = Pcg64::with_stream(seed, (base + j) as u64);
                        let (zz, rr) = quantize_block(
                            plan,
                            &data_c[lo..hi],
                            &mut codes_c[lo..hi],
                            &mut rng_g,
                        );
                        *z = zz;
                        *r = rr;
                    }
                }));
            }
            self.pool.run(tasks);
        }

        let mut packed = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_empty(total_bytes),
            None => Vec::new(),
        };
        // Width was validated by `QuantPlan::resolve` above, so the
        // infallible ISA-dispatched slice packer applies directly.
        packed.resize(total_bytes, 0);
        pack_codes_slice_isa(&codes, bits, &mut packed, self.codec_isa);
        if let Some(p) = pool.as_deref_mut() {
            p.put_bytes(codes);
        }
        Ok(CompressedTensor {
            packed,
            zeros,
            ranges,
            shape: h.shape(),
            group_len,
            bits,
            bins: bins.clone(),
        })
    }

    /// Dequantize (Eq. 3), sharding the group loop across worker threads.
    /// Purely deterministic, so parallel and serial results are
    /// bit-identical by construction.
    pub fn dequantize(&self, ct: &CompressedTensor) -> Result<Matrix> {
        self.dequantize_impl(ct, None)
    }

    /// [`Self::dequantize`] with the output buffer drawn from `pool`
    /// (the fused decoder needs no byte scratch).
    pub fn dequantize_pooled(
        &self,
        ct: &CompressedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        self.dequantize_impl(ct, Some(pool))
    }

    fn dequantize_impl(
        &self,
        ct: &CompressedTensor,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<Matrix> {
        validate_compressed(ct)?;
        let (rows, cols) = ct.shape;
        let n = rows * cols;
        let num_groups = n.div_ceil(ct.group_len);
        let plan = DequantPlan::resolve(ct.bits, &ct.bins);
        let group_len = ct.group_len;
        // Every element of `out` is overwritten group by group, so an
        // unspecified-content take is safe. The fused decoder maps
        // packed bytes straight to floats — the decode→codes→floats
        // double pass (and its per-shard byte scratch) is gone.
        let mut out = match pool.as_deref_mut() {
            Some(p) => p.take_floats_scratch(n),
            None => vec![0f32; n],
        };

        let isa = self.codec_isa;
        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                unpack_dequantize_block_tiled(
                    &plan,
                    ct.zeros[g],
                    ct.ranges[g],
                    &ct.packed,
                    start,
                    &mut out[start..end],
                    isa,
                );
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let plan = &plan;
            let packed = ct.packed.as_slice();
            let zeros = ct.zeros.as_slice();
            let ranges = ct.ranges.as_slice();
            let mut tasks: Vec<Task<'_>> =
                Vec::with_capacity(num_groups.div_ceil(groups_per_shard));
            for (idx, ((out_c, zeros_c), ranges_c)) in out
                .chunks_mut(chunk)
                .zip(zeros.chunks(groups_per_shard))
                .zip(ranges.chunks(groups_per_shard))
                .enumerate()
            {
                tasks.push(Box::new(move || {
                    // Each shard decodes only its own scalar range —
                    // in-bounds by the packed-length check above.
                    let base = idx * chunk;
                    for (j, (&z, &r)) in zeros_c.iter().zip(ranges_c).enumerate() {
                        let lo = j * group_len;
                        let hi = (lo + group_len).min(out_c.len());
                        unpack_dequantize_block_tiled(
                            plan,
                            z,
                            r,
                            packed,
                            base + lo,
                            &mut out_c[lo..hi],
                            isa,
                        );
                    }
                }));
            }
            self.pool.run(tasks);
        }
        Matrix::from_vec(rows, cols, out)
    }

    /// Grouped quantization under a heterogeneous [`BitPlan`]: block `g`
    /// is quantized at `plan.bit(g)` with uniform bins, packed
    /// byte-aligned at `plan.offsets(n)[g]`. One `u64` draw from `rng`
    /// keys the per-block streams, exactly like [`Self::quantize`].
    ///
    /// ```
    /// use iexact::alloc::BitPlan;
    /// use iexact::engine::QuantEngine;
    /// use iexact::rngs::Pcg64;
    /// use iexact::tensor::Matrix;
    ///
    /// let mut rng = Pcg64::new(3);
    /// let h = Matrix::from_fn(4, 16, |_, _| rng.next_f32());
    /// // 4 blocks of 16 scalars at 1/2/4/8 bits.
    /// let plan = BitPlan::new(vec![1, 2, 4, 8], 16).unwrap();
    /// let pt = QuantEngine::serial().quantize_planned(&h, &plan, &mut rng).unwrap();
    /// assert_eq!(pt.num_groups(), 4);
    /// assert_eq!(pt.packed.len(), 2 + 4 + 8 + 16);
    /// assert_eq!(pt.dequantize().unwrap().shape(), (4, 16));
    /// ```
    pub fn quantize_planned(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        rng: &mut Pcg64,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_seeded(h, plan, rng.next_u64())
    }

    /// Seed-addressed planned quantization — bit-identical across
    /// engines for every `BitPlan`, like [`Self::quantize_seeded`].
    pub fn quantize_planned_seeded(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, seed, None)
    }

    /// [`Self::quantize_planned`] with the packed buffer recycled
    /// through `pool` (the fused packer needs no code scratch).
    pub fn quantize_planned_pooled(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        rng: &mut Pcg64,
        pool: &mut BufferPool,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, rng.next_u64(), Some(pool))
    }

    /// Seed-addressed **and** pooled planned quantization: the
    /// idempotent entry point behind
    /// [`ActivationCache::park`](crate::memory::ActivationCache::park) —
    /// re-quantizing the same matrix under the same seed reproduces the
    /// same bytes while still recycling buffers through `pool`.
    pub fn quantize_planned_seeded_pooled(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
        pool: &mut BufferPool,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, seed, Some(pool))
    }

    fn quantize_planned_impl(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<PlannedTensor> {
        let data = h.as_slice();
        let n = data.len();
        let group_len = plan.group_len();
        let num_groups = plan.num_blocks();
        let offsets = plan.offsets(n)?; // also validates plan coverage
        let total_bytes = *offsets.last().expect("offsets non-empty");

        // Resolve one fixed-width QuantPlan per width the plan uses —
        // all with uniform bins (the VM bin layout is INT2-specific and
        // belongs to the fixed-width RowWiseVm mode).
        let mut qplans: [Option<QuantPlan>; 4] = [None, None, None, None];
        for &b in plan.bits() {
            let slot = width_slot(b as u32);
            if qplans[slot].is_none() {
                qplans[slot] = Some(QuantPlan::resolve(b as u32, &BinSpec::Uniform, group_len)?);
            }
        }

        let mut zeros = vec![0f32; num_groups];
        let mut ranges = vec![0f32; num_groups];
        // Every byte of `packed` is written by quantize_pack_block
        // (blocks are byte-aligned, partial final bytes zero-padded), so
        // an unspecified-content take is safe. Heterogeneous blocks are
        // always byte-aligned, so the planned packer is unconditionally
        // fused: SR rounds straight into each block's byte range and no
        // worker allocates a code tile.
        let mut packed = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_scratch(total_bytes),
            None => vec![0u8; total_bytes],
        };

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let lo = g * group_len;
                let hi = (lo + group_len).min(n);
                let bits = plan.bit(g);
                let qp = qplans[width_slot(bits)].as_ref().expect("resolved above");
                let mut rng_g = Pcg64::with_stream(seed, g as u64);
                let (z, r) = quantize_pack_block(
                    qp,
                    &data[lo..hi],
                    &mut packed[offsets[g]..offsets[g + 1]],
                    &mut rng_g,
                );
                zeros[g] = z;
                ranges[g] = r;
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let shard_count = num_groups.div_ceil(groups_per_shard);
            // Split the packed buffer at shard boundaries (blocks are
            // byte-aligned, so shard ranges are disjoint byte ranges).
            let mut packed_chunks: Vec<&mut [u8]> = Vec::with_capacity(shard_count);
            let mut rest: &mut [u8] = packed.as_mut_slice();
            let mut consumed = 0usize;
            for i in 0..shard_count {
                let end = offsets[((i + 1) * groups_per_shard).min(num_groups)];
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(end - consumed);
                packed_chunks.push(head);
                rest = tail;
                consumed = end;
            }
            let offsets = offsets.as_slice();
            let qplans = &qplans;
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shard_count);
            for (i, ((packed_c, zeros_c), ranges_c)) in packed_chunks
                .into_iter()
                .zip(zeros.chunks_mut(groups_per_shard))
                .zip(ranges.chunks_mut(groups_per_shard))
                .enumerate()
            {
                tasks.push(Box::new(move || {
                    let base = i * groups_per_shard;
                    let base_off = offsets[base];
                    for (j, (z, r)) in
                        zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                    {
                        let g = base + j;
                        let lo = g * group_len;
                        let hi = (lo + group_len).min(n);
                        let bits = plan.bit(g);
                        let qp =
                            qplans[width_slot(bits)].as_ref().expect("resolved above");
                        let mut rng_g = Pcg64::with_stream(seed, g as u64);
                        let (zz, rr) = quantize_pack_block(
                            qp,
                            &data[lo..hi],
                            &mut packed_c[offsets[g] - base_off..offsets[g + 1] - base_off],
                            &mut rng_g,
                        );
                        *z = zz;
                        *r = rr;
                    }
                }));
            }
            self.pool.run(tasks);
        }

        Ok(PlannedTensor {
            packed,
            zeros,
            ranges,
            shape: h.shape(),
            plan: plan.clone(),
        })
    }

    /// Quantize `h` under `plan` at `seed` and serialize the result into
    /// a wire body — the send side of the distributed halo exchange. The
    /// body layout is exactly the spill-file body (shape, plan header,
    /// metadata floats, packed codes; see
    /// `crate::memory::write_planned`), so the activations cross process
    /// boundaries **as packed codes**, never as dense `f32`. The
    /// intermediate packed buffer recycles through `pool`.
    pub fn pack_to_wire(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
        pool: &mut BufferPool,
    ) -> Result<Vec<u8>> {
        let pt = self.quantize_planned_seeded_pooled(h, plan, seed, pool)?;
        let mut buf = Vec::with_capacity(64 + pt.nbytes() + pt.plan.num_blocks());
        crate::memory::write_planned(&mut buf, &pt);
        pool.put_bytes(pt.packed);
        Ok(buf)
    }

    /// Decode a [`Self::pack_to_wire`] body back into a
    /// [`PlannedTensor`] — the receive side of the halo exchange. The
    /// tensor stays in packed-code form (park it, ship it on, or
    /// dequantize via [`Self::dequantize_planned_pooled`]); malformed
    /// bodies surface named `wire planned tensor` errors, never panics.
    pub fn decode_from_wire(
        &self,
        bytes: &[u8],
        pool: &mut BufferPool,
    ) -> Result<PlannedTensor> {
        let mut r = crate::checkpoint::Reader {
            cur: bytes,
            what: "wire planned tensor",
        };
        let pt = crate::memory::read_planned(&mut r, pool)?;
        if !r.cur.is_empty() {
            pool.put_bytes(pt.packed);
            return Err(crate::Error::Artifact(
                "wire planned tensor: trailing bytes".into(),
            ));
        }
        // Structural cross-checks happen HERE, at the trust boundary —
        // a peer-supplied body whose shape, plan, metadata counts and
        // packed length disagree must be rejected by name on receipt,
        // not crash some later decode.
        match validate_planned(&pt) {
            Err(e) => {
                let msg = format!("wire planned tensor: inconsistent body: {e}");
                pool.put_bytes(pt.packed);
                Err(crate::Error::Artifact(msg))
            }
            Ok(offsets) => {
                let total = *offsets.last().expect("offsets non-empty");
                if pt.packed.len() != total {
                    let msg = format!(
                        "wire planned tensor: packed body has {} bytes, plan needs {total}",
                        pt.packed.len()
                    );
                    pool.put_bytes(pt.packed);
                    return Err(crate::Error::Artifact(msg));
                }
                Ok(pt)
            }
        }
    }

    /// Dequantize a [`PlannedTensor`] (Eq. 3 per block, each at its own
    /// width), sharding the block loop across worker threads. Purely
    /// deterministic — parallel and serial results are bit-identical.
    pub fn dequantize_planned(&self, pt: &PlannedTensor) -> Result<Matrix> {
        self.dequantize_planned_impl(pt, None)
    }

    /// [`Self::dequantize_planned`] with the output buffer drawn from
    /// `pool` (the fused decoder needs no byte scratch).
    pub fn dequantize_planned_pooled(
        &self,
        pt: &PlannedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        self.dequantize_planned_impl(pt, Some(pool))
    }

    fn dequantize_planned_impl(
        &self,
        pt: &PlannedTensor,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<Matrix> {
        let (rows, cols) = pt.shape;
        let n = rows * cols;
        let group_len = pt.plan.group_len();
        let num_groups = pt.plan.num_blocks();
        let offsets = validate_planned(pt)?;
        let mut dplans: [Option<DequantPlan>; 4] = [None, None, None, None];
        for &b in pt.plan.bits() {
            let slot = width_slot(b as u32);
            if dplans[slot].is_none() {
                dplans[slot] = Some(DequantPlan::resolve(b as u32, &BinSpec::Uniform));
            }
        }
        let mut out = match pool.as_deref_mut() {
            Some(p) => p.take_floats_scratch(n),
            None => vec![0f32; n],
        };

        let isa = self.codec_isa;
        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let lo = g * group_len;
                let hi = (lo + group_len).min(n);
                let bits = pt.plan.bit(g);
                let dp = dplans[width_slot(bits)].as_ref().expect("resolved above");
                unpack_dequantize_block_tiled(
                    dp,
                    pt.zeros[g],
                    pt.ranges[g],
                    &pt.packed[offsets[g]..offsets[g + 1]],
                    0,
                    &mut out[lo..hi],
                    isa,
                );
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let offsets = offsets.as_slice();
            let dplans = &dplans;
            let packed = pt.packed.as_slice();
            let zeros = pt.zeros.as_slice();
            let ranges = pt.ranges.as_slice();
            let plan = &pt.plan;
            let mut tasks: Vec<Task<'_>> = Vec::new();
            for (i, out_c) in out.chunks_mut(chunk).enumerate() {
                tasks.push(Box::new(move || {
                    let base = i * groups_per_shard;
                    let blocks = out_c.len().div_ceil(group_len);
                    for j in 0..blocks {
                        let g = base + j;
                        let lo = j * group_len;
                        let hi = (lo + group_len).min(out_c.len());
                        let bits = plan.bit(g);
                        let dp =
                            dplans[width_slot(bits)].as_ref().expect("resolved above");
                        unpack_dequantize_block_tiled(
                            dp,
                            zeros[g],
                            ranges[g],
                            &packed[offsets[g]..offsets[g + 1]],
                            0,
                            &mut out_c[lo..hi],
                            isa,
                        );
                    }
                }));
            }
            self.pool.run(tasks);
        }
        Matrix::from_vec(rows, cols, out)
    }

    /// Fused `Dequant(ct) @ b` — the backward pass's unstash→recover
    /// product — without materializing the dense dequantized matrix.
    ///
    /// Blocks are decoded one at a time into a per-worker scratch tile
    /// (recycled through `pool`) and each decoded row is streamed
    /// straight into the output via the same row kernel
    /// [`Matrix::matmul`] uses, so the result is **bit-identical** to
    /// `engine.dequantize(ct)? @ b` at any thread count while peak
    /// intermediate memory drops from the full `rows × cols` matrix to
    /// `group_len` floats per worker.
    ///
    /// Requires the stash's blocks to be row-aligned
    /// (`group_len % cols == 0`, which holds for every stash the
    /// pipeline produces — per-row and block-wise grouping are both
    /// whole-row). Non-aligned tensors fall back to
    /// materialize-then-multiply.
    pub fn dequantize_matmul(
        &self,
        ct: &CompressedTensor,
        b: &Matrix,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        validate_compressed(ct)?;
        let (rows, cols) = ct.shape;
        let n_scalars = rows * cols;
        if b.rows() != cols {
            return Err(Error::Shape(format!(
                "dequantize_matmul: {rows}x{cols} @ {}x{}",
                b.rows(),
                b.cols()
            )));
        }
        if cols == 0 || ct.group_len % cols != 0 {
            let deq = self.dequantize_pooled(ct, pool)?;
            let out = deq.matmul_with(b, &self.pool)?;
            pool.put_floats(deq.into_vec());
            return Ok(out);
        }
        let dec = BlockDecoder {
            packed: &ct.packed,
            zeros: &ct.zeros,
            ranges: &ct.ranges,
            group_len: ct.group_len,
            n_scalars,
            isa: self.codec_isa,
            layout: DecodeLayout::Fixed {
                plan: DequantPlan::resolve(ct.bits, &ct.bins),
            },
        };
        self.fused_matmul(&dec, (rows, cols), b, pool)
    }

    /// [`Self::dequantize_matmul`] for a heterogeneous [`PlannedTensor`]:
    /// walks the plan's byte-aligned packed blocks, decoding each at its
    /// own width. Bit-identical to
    /// `engine.dequantize_planned(pt)? @ b` at any thread count.
    pub fn dequantize_matmul_planned(
        &self,
        pt: &PlannedTensor,
        b: &Matrix,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let (rows, cols) = pt.shape;
        let n_scalars = rows * cols;
        let offsets = validate_planned(pt)?;
        if b.rows() != cols {
            return Err(Error::Shape(format!(
                "dequantize_matmul: {rows}x{cols} @ {}x{}",
                b.rows(),
                b.cols()
            )));
        }
        if cols == 0 || pt.plan.group_len() % cols != 0 {
            let deq = self.dequantize_planned_pooled(pt, pool)?;
            let out = deq.matmul_with(b, &self.pool)?;
            pool.put_floats(deq.into_vec());
            return Ok(out);
        }
        let dec = BlockDecoder {
            packed: &pt.packed,
            zeros: &pt.zeros,
            ranges: &pt.ranges,
            group_len: pt.plan.group_len(),
            n_scalars,
            isa: self.codec_isa,
            layout: DecodeLayout::planned(&pt.plan, &offsets),
        };
        self.fused_matmul(&dec, (rows, cols), b, pool)
    }

    /// Fused `adj @ Dequant(pt)` — compressed-activation aggregation —
    /// without materializing the dense dequantized matrix.
    ///
    /// Output rows are sharded across the pool exactly like
    /// [`CsrMatrix::spmm_with`]; each worker keeps **one decoded block**
    /// (`group_len` floats, recycled through `pool`) as its tile cache
    /// and re-decodes on block change. Because every output row
    /// accumulates its CSR neighbors in the serial order over identical
    /// decoded values, the result is **bit-identical** to
    /// `adj.spmm(&engine.dequantize_planned(pt)?)` at any thread count.
    ///
    /// Requires row-aligned blocks (`group_len % cols == 0`); non-aligned
    /// plans fall back to materialize-then-aggregate.
    ///
    /// **Cost model:** decode work is `O(block switches × group_len)` —
    /// a block is re-decoded whenever consecutive CSR neighbors fall in
    /// different blocks, so the fused kernel trades decode time for
    /// memory. On neighbor-local graphs (sorted CSR columns, clustered
    /// or partitioned node orders) switches are rare and the kernel is
    /// competitive; on scatter-heavy adjacencies materialize-then-
    /// aggregate can be faster while the fused path still wins on peak
    /// memory (one `group_len` tile per worker vs the full dense
    /// matrix). `bench_pipeline`'s `fused` group measures both arms so
    /// the trade-off is recorded, not assumed.
    pub fn dequantize_spmm_planned(
        &self,
        adj: &CsrMatrix,
        pt: &PlannedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let (rows, cols) = pt.shape;
        let n_scalars = rows * cols;
        let offsets = validate_planned(pt)?;
        if adj.n_cols != rows {
            return Err(Error::Shape(format!(
                "dequantize_spmm: {}x{} @ {rows}x{cols}",
                adj.n_rows, adj.n_cols
            )));
        }
        if cols == 0 {
            return Ok(Matrix::zeros(adj.n_rows, 0));
        }
        if pt.plan.group_len() % cols != 0 {
            let deq = self.dequantize_planned_pooled(pt, pool)?;
            let out = adj.spmm_with(&deq, &self.pool)?;
            pool.put_floats(deq.into_vec());
            return Ok(out);
        }
        let dec = BlockDecoder {
            packed: &pt.packed,
            zeros: &pt.zeros,
            ranges: &pt.ranges,
            group_len: pt.plan.group_len(),
            n_scalars,
            isa: self.codec_isa,
            layout: DecodeLayout::planned(&pt.plan, &offsets),
        };
        self.fused_spmm(adj, &dec, cols, pool)
    }

    /// Decode **only the listed rows** of a row-aligned
    /// [`PlannedTensor`] into a `rows.len() × cols` matrix — the serving
    /// read path's touched-row entry point. Each worker keeps one
    /// decoded block (`group_len` floats, recycled through `pool`) as
    /// its tile cache, so peak intermediate memory is one block per
    /// worker regardless of how many rows the tensor holds; the dense
    /// `N × R` matrix is never materialized
    /// ([`PoolStats::max_float_take`](crate::memory::PoolStats) proves
    /// it). Bit-identical to gathering the same rows from
    /// [`Self::dequantize_planned`] at any thread count and ISA.
    ///
    /// Requires row-aligned blocks (`group_len % cols == 0`) — the
    /// layout every pipeline stash and every serving store uses; a
    /// non-aligned plan is a named [`Error::Config`] (a serving store
    /// must *never* silently fall back to a dense decode).
    pub fn dequantize_rows_planned(
        &self,
        pt: &PlannedTensor,
        rows: &[usize],
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let (n_rows, cols) = pt.shape;
        let offsets = validate_planned(pt)?;
        let group_len = pt.plan.group_len();
        if cols == 0 || group_len % cols != 0 {
            return Err(Error::Config(format!(
                "dequantize_rows_planned needs row-aligned blocks \
                 (group_len {group_len} % cols {cols} != 0)"
            )));
        }
        if let Some(&bad) = rows.iter().find(|&&r| r >= n_rows) {
            return Err(Error::Shape(format!(
                "row index {bad} out of range for {n_rows}-row tensor"
            )));
        }
        let dec = BlockDecoder {
            packed: &pt.packed,
            zeros: &pt.zeros,
            ranges: &pt.ranges,
            group_len,
            n_scalars: n_rows * cols,
            isa: self.codec_isa,
            layout: DecodeLayout::planned(&pt.plan, &offsets),
        };
        let rows_per_block = group_len / cols;
        let mut out = Matrix::zeros(rows.len(), cols);
        if rows.is_empty() {
            return Ok(out);
        }
        let shards = self.pool.shards_for(rows.len(), MIN_ROWS_PER_SHARD);
        if shards <= 1 {
            let mut floats = pool.take_floats_scratch(group_len);
            let mut cached = usize::MAX;
            let out_data = out.as_mut_slice();
            for (i, &r) in rows.iter().enumerate() {
                let g = r / rows_per_block;
                if g != cached {
                    dec.decode(g, &mut floats);
                    cached = g;
                }
                let off = (r - g * rows_per_block) * cols;
                out_data[i * cols..(i + 1) * cols].copy_from_slice(&floats[off..off + cols]);
            }
            pool.put_floats(floats);
        } else {
            let rows_per = rows.len().div_ceil(shards);
            let shard_count = rows.len().div_ceil(rows_per);
            let mut float_scr: Vec<Vec<f32>> = (0..shard_count)
                .map(|_| pool.take_floats_scratch(group_len))
                .collect();
            let dec = &dec;
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shard_count);
            for ((rows_c, out_c), floats) in rows
                .chunks(rows_per)
                .zip(out.as_mut_slice().chunks_mut(rows_per * cols))
                .zip(float_scr.iter_mut())
            {
                tasks.push(Box::new(move || {
                    let mut cached = usize::MAX;
                    for (&r, out_row) in rows_c.iter().zip(out_c.chunks_mut(cols)) {
                        let g = r / rows_per_block;
                        if g != cached {
                            dec.decode(g, floats);
                            cached = g;
                        }
                        let off = (r - g * rows_per_block) * cols;
                        out_row.copy_from_slice(&floats[off..off + cols]);
                    }
                }));
            }
            self.pool.run(tasks);
            for f in float_scr {
                pool.put_floats(f);
            }
        }
        Ok(out)
    }

    /// Decode an explicit **block list**: block `blocks[i]` lands at
    /// `out[i * group_len ..]` (only `block_len` floats are written for
    /// a ragged final block). This is the shared-decode-tile primitive
    /// behind the serving batcher — a batch of overlapping queries
    /// computes its sorted-unique touched-block set once, decodes each
    /// block **exactly once** here, and answers every query from the
    /// resulting tile arena. The block loop shards across the engine's
    /// [`WorkerPool`]; decode is deterministic, so the arena is
    /// bit-identical to the corresponding slices of
    /// [`Self::dequantize_planned`] at any thread count.
    pub fn decode_blocks_planned(
        &self,
        pt: &PlannedTensor,
        blocks: &[usize],
        out: &mut [f32],
    ) -> Result<()> {
        let offsets = validate_planned(pt)?;
        let group_len = pt.plan.group_len();
        let num_groups = pt.plan.num_blocks();
        if let Some(&bad) = blocks.iter().find(|&&g| g >= num_groups) {
            return Err(Error::Shape(format!(
                "block index {bad} out of range for {num_groups}-block plan"
            )));
        }
        if out.len() < blocks.len() * group_len {
            return Err(Error::Shape(format!(
                "decode_blocks_planned: output holds {} floats, {} blocks need {}",
                out.len(),
                blocks.len(),
                blocks.len() * group_len
            )));
        }
        if blocks.is_empty() {
            return Ok(());
        }
        let (rows, cols) = pt.shape;
        let dec = BlockDecoder {
            packed: &pt.packed,
            zeros: &pt.zeros,
            ranges: &pt.ranges,
            group_len,
            n_scalars: rows * cols,
            isa: self.codec_isa,
            layout: DecodeLayout::planned(&pt.plan, &offsets),
        };
        let shards = self.effective_shards(blocks.len());
        if shards <= 1 {
            for (&g, tile) in blocks.iter().zip(out.chunks_mut(group_len)) {
                dec.decode(g, tile);
            }
        } else {
            let per_shard = blocks.len().div_ceil(shards);
            let dec = &dec;
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shards);
            for (blocks_c, out_c) in blocks
                .chunks(per_shard)
                .zip(out.chunks_mut(per_shard * group_len))
            {
                tasks.push(Box::new(move || {
                    for (&g, tile) in blocks_c.iter().zip(out_c.chunks_mut(group_len)) {
                        dec.decode(g, tile);
                    }
                }));
            }
            self.pool.run(tasks);
        }
        Ok(())
    }

    /// Fused `adj @ Dequant(pt)` restricted to the listed **output
    /// rows** — the serving scorer. Row `out_rows[i]` of the result is
    /// the CSR-neighborhood aggregation of output row `out_rows[i]`,
    /// accumulated in the same serial order over the same decoded
    /// values as [`Self::dequantize_spmm_planned`], so the returned
    /// `out_rows.len() × cols` matrix is **bit-identical** to gathering
    /// those rows from the full product. One decoded block per worker;
    /// the dense operand is never materialized.
    ///
    /// Requires row-aligned blocks like
    /// [`Self::dequantize_rows_planned`] (named [`Error::Config`]
    /// otherwise).
    pub fn dequantize_spmm_rows_planned(
        &self,
        adj: &CsrMatrix,
        pt: &PlannedTensor,
        out_rows: &[usize],
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let (rows, cols) = pt.shape;
        let offsets = validate_planned(pt)?;
        let group_len = pt.plan.group_len();
        if adj.n_cols != rows {
            return Err(Error::Shape(format!(
                "dequantize_spmm_rows: {}x{} @ {rows}x{cols}",
                adj.n_rows, adj.n_cols
            )));
        }
        if cols == 0 || group_len % cols != 0 {
            return Err(Error::Config(format!(
                "dequantize_spmm_rows_planned needs row-aligned blocks \
                 (group_len {group_len} % cols {cols} != 0)"
            )));
        }
        if let Some(&bad) = out_rows.iter().find(|&&r| r >= adj.n_rows) {
            return Err(Error::Shape(format!(
                "output row {bad} out of range for {}-row adjacency",
                adj.n_rows
            )));
        }
        let dec = BlockDecoder {
            packed: &pt.packed,
            zeros: &pt.zeros,
            ranges: &pt.ranges,
            group_len,
            n_scalars: rows * cols,
            isa: self.codec_isa,
            layout: DecodeLayout::planned(&pt.plan, &offsets),
        };
        let rows_per_block = group_len / cols;
        let mut out = Matrix::zeros(out_rows.len(), cols);
        if out_rows.is_empty() {
            return Ok(out);
        }
        let shards = self.pool.shards_for(out_rows.len(), MIN_ROWS_PER_SHARD);
        if shards <= 1 {
            let mut floats = pool.take_floats_scratch(group_len);
            let mut cached = usize::MAX;
            let out_data = out.as_mut_slice();
            for (i, &r) in out_rows.iter().enumerate() {
                let (idx, vals) = adj.row(r);
                fused_spmm_row(
                    idx,
                    vals,
                    &dec,
                    rows_per_block,
                    cols,
                    &mut cached,
                    &mut floats,
                    &mut out_data[i * cols..(i + 1) * cols],
                );
            }
            pool.put_floats(floats);
        } else {
            let rows_per = out_rows.len().div_ceil(shards);
            let shard_count = out_rows.len().div_ceil(rows_per);
            let mut float_scr: Vec<Vec<f32>> = (0..shard_count)
                .map(|_| pool.take_floats_scratch(group_len))
                .collect();
            let dec = &dec;
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shard_count);
            for ((rows_c, out_c), floats) in out_rows
                .chunks(rows_per)
                .zip(out.as_mut_slice().chunks_mut(rows_per * cols))
                .zip(float_scr.iter_mut())
            {
                tasks.push(Box::new(move || {
                    let mut cached = usize::MAX;
                    for (&r, out_row) in rows_c.iter().zip(out_c.chunks_mut(cols)) {
                        let (idx, vals) = adj.row(r);
                        fused_spmm_row(
                            idx,
                            vals,
                            dec,
                            rows_per_block,
                            cols,
                            &mut cached,
                            floats,
                            out_row,
                        );
                    }
                }));
            }
            self.pool.run(tasks);
            for f in float_scr {
                pool.put_floats(f);
            }
        }
        Ok(out)
    }

    /// Shared core of the fused dequantize→matmul kernels: shard the
    /// block list, decode block-by-block into per-worker scratch, stream
    /// each decoded row through [`row_axpy_matmul`] into the output.
    fn fused_matmul(
        &self,
        dec: &BlockDecoder<'_>,
        shape: (usize, usize),
        b: &Matrix,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let (rows, cols) = shape;
        let n = b.cols();
        let mut out = Matrix::zeros(rows, n);
        let num_groups = dec.num_groups();
        if rows == 0 || n == 0 || num_groups == 0 {
            return Ok(out);
        }
        let group_len = dec.group_len;
        let rows_per_block = group_len / cols;
        let b_data = b.as_slice();
        // Gate fan-out on *output rows* like the dense kernels (16-row
        // minimum tile), not on the quantizer's block gate: stash block
        // counts are small (hundreds) under production group lengths,
        // and the work per block here is a matmul row, not a quantize
        // loop. Shards are still block-aligned (one shard ≥ one block).
        let shards = self
            .pool
            .shards_for(rows, MIN_ROWS_PER_SHARD)
            .min(num_groups);
        if shards <= 1 {
            let mut floats = pool.take_floats_scratch(group_len);
            let out_data = out.as_mut_slice();
            for g in 0..num_groups {
                let len = dec.decode(g, &mut floats);
                let row0 = g * rows_per_block;
                for (i, a_row) in floats[..len].chunks(cols).enumerate() {
                    let r = row0 + i;
                    row_axpy_matmul(a_row, b_data, n, &mut out_data[r * n..(r + 1) * n]);
                }
            }
            pool.put_floats(floats);
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let shard_count = num_groups.div_ceil(groups_per_shard);
            let chunk = groups_per_shard * rows_per_block * n;
            let mut float_scr: Vec<Vec<f32>> = (0..shard_count)
                .map(|_| pool.take_floats_scratch(group_len))
                .collect();
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shard_count);
            for ((i, out_c), floats) in out
                .as_mut_slice()
                .chunks_mut(chunk)
                .enumerate()
                .zip(float_scr.iter_mut())
            {
                tasks.push(Box::new(move || {
                    let base = i * groups_per_shard;
                    let blocks = (out_c.len() / n).div_ceil(rows_per_block);
                    for j in 0..blocks {
                        let g = base + j;
                        let len = dec.decode(g, floats);
                        let lo_row = j * rows_per_block;
                        for (ri, a_row) in floats[..len].chunks(cols).enumerate() {
                            let r = lo_row + ri;
                            row_axpy_matmul(
                                a_row,
                                b_data,
                                n,
                                &mut out_c[r * n..(r + 1) * n],
                            );
                        }
                    }
                }));
            }
            self.pool.run(tasks);
            for f in float_scr {
                pool.put_floats(f);
            }
        }
        Ok(out)
    }

    /// Shared core of the fused dequantize→spmm kernel: shard *output*
    /// rows, cache one decoded block per worker, accumulate CSR
    /// neighbors in serial order.
    fn fused_spmm(
        &self,
        adj: &CsrMatrix,
        dec: &BlockDecoder<'_>,
        cols: usize,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        let mut out = Matrix::zeros(adj.n_rows, cols);
        if adj.n_rows == 0 || cols == 0 || dec.n_scalars == 0 {
            return Ok(out);
        }
        let group_len = dec.group_len;
        let rows_per_block = group_len / cols;
        let shards = self.pool.shards_for(adj.n_rows, MIN_ROWS_PER_SHARD);
        if shards <= 1 {
            let mut floats = pool.take_floats_scratch(group_len);
            let mut cached = usize::MAX;
            let out_data = out.as_mut_slice();
            for r in 0..adj.n_rows {
                let (idx, vals) = adj.row(r);
                let out_row = &mut out_data[r * cols..(r + 1) * cols];
                fused_spmm_row(
                    idx,
                    vals,
                    dec,
                    rows_per_block,
                    cols,
                    &mut cached,
                    &mut floats,
                    out_row,
                );
            }
            pool.put_floats(floats);
        } else {
            let rows_per = adj.n_rows.div_ceil(shards);
            let shard_count = adj.n_rows.div_ceil(rows_per);
            let mut float_scr: Vec<Vec<f32>> = (0..shard_count)
                .map(|_| pool.take_floats_scratch(group_len))
                .collect();
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(shard_count);
            for ((tile, out_c), floats) in out
                .as_mut_slice()
                .chunks_mut(rows_per * cols)
                .enumerate()
                .zip(float_scr.iter_mut())
            {
                let base = tile * rows_per;
                tasks.push(Box::new(move || {
                    let mut cached = usize::MAX;
                    for (i, out_row) in out_c.chunks_mut(cols).enumerate() {
                        let (idx, vals) = adj.row(base + i);
                        fused_spmm_row(
                            idx,
                            vals,
                            dec,
                            rows_per_block,
                            cols,
                            &mut cached,
                            floats,
                            out_row,
                        );
                    }
                }));
            }
            self.pool.run(tasks);
            for f in float_scr {
                pool.put_floats(f);
            }
        }
        Ok(out)
    }
}

/// One fused-spmm output row: accumulate `v · x̂[c]` over CSR neighbors
/// in order, decoding the block holding row `c` into the worker's tile
/// cache on block change. The inner accumulation mirrors the serial
/// `spmm_row` kernel in `graph.rs` exactly — the bit-identity contract
/// with the materialize-then-aggregate path.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fused_spmm_row(
    idx: &[usize],
    vals: &[f32],
    dec: &BlockDecoder<'_>,
    rows_per_block: usize,
    cols: usize,
    cached: &mut usize,
    floats: &mut [f32],
    out_row: &mut [f32],
) {
    for (&c, &v) in idx.iter().zip(vals) {
        let g = c / rows_per_block;
        if g != *cached {
            dec.decode(g, floats);
            *cached = g;
        }
        let off = (c - g * rows_per_block) * cols;
        let h_row = &floats[off..off + cols];
        for j in 0..cols {
            out_row[j] += v * h_row[j];
        }
    }
}

/// Read-only view of one compressed stash's packed blocks plus resolved
/// dequantization plans — the shared substrate of the fused kernels.
/// Decoding is purely deterministic, so sharing it across workers keeps
/// the serial/parallel bit-identity contract.
struct BlockDecoder<'a> {
    packed: &'a [u8],
    zeros: &'a [f32],
    ranges: &'a [f32],
    group_len: usize,
    n_scalars: usize,
    isa: CodecIsa,
    layout: DecodeLayout<'a>,
}

enum DecodeLayout<'a> {
    /// Fixed-width contiguous stream: block `g` starts at scalar
    /// `g * group_len` of one packed bitstream.
    Fixed { plan: DequantPlan },
    /// Heterogeneous widths: block `g` occupies its own byte-aligned
    /// packed range at `offsets[g]..offsets[g + 1]`.
    Planned {
        offsets: &'a [usize],
        plan: &'a BitPlan,
        dplans: Box<[Option<DequantPlan>; 4]>,
    },
}

impl<'a> DecodeLayout<'a> {
    /// Resolve one [`DequantPlan`] per width `plan` actually uses
    /// (uniform bins — the planned path's contract).
    fn planned(plan: &'a BitPlan, offsets: &'a [usize]) -> Self {
        let mut dplans: Box<[Option<DequantPlan>; 4]> = Box::new([None, None, None, None]);
        for &b in plan.bits() {
            let slot = width_slot(b as u32);
            if dplans[slot].is_none() {
                dplans[slot] = Some(DequantPlan::resolve(b as u32, &BinSpec::Uniform));
            }
        }
        DecodeLayout::Planned {
            offsets,
            plan,
            dplans,
        }
    }
}

impl BlockDecoder<'_> {
    fn num_groups(&self) -> usize {
        self.n_scalars.div_ceil(self.group_len)
    }

    /// Scalars in block `g` (only the final block may be ragged).
    fn block_len(&self, g: usize) -> usize {
        self.group_len.min(self.n_scalars - g * self.group_len)
    }

    /// Decode block `g` straight into `floats[..len]` (fused unpack→
    /// LUT-dequantize; no code scratch) and return `len`.
    fn decode(&self, g: usize, floats: &mut [f32]) -> usize {
        let len = self.block_len(g);
        let out = &mut floats[..len];
        match &self.layout {
            DecodeLayout::Fixed { plan } => {
                unpack_dequantize_block_tiled(
                    plan,
                    self.zeros[g],
                    self.ranges[g],
                    self.packed,
                    g * self.group_len,
                    out,
                    self.isa,
                );
            }
            DecodeLayout::Planned {
                offsets,
                plan,
                dplans,
            } => {
                let bits = plan.bit(g);
                let dp = dplans[width_slot(bits)]
                    .as_ref()
                    .expect("plan resolved per used width");
                unpack_dequantize_block_tiled(
                    dp,
                    self.zeros[g],
                    self.ranges[g],
                    &self.packed[offsets[g]..offsets[g + 1]],
                    0,
                    out,
                    self.isa,
                );
            }
        }
        len
    }
}

impl Default for QuantEngine {
    /// Defaults to [`Self::auto`].
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 4.0 - 2.0)
    }

    #[test]
    fn effective_shards_respects_gating() {
        let e = QuantEngine::from_config(&ParallelismConfig {
            threads: 8,
            min_blocks_per_shard: 100,
            ..ParallelismConfig::default()
        });
        assert_eq!(e.effective_shards(50), 1); // too few blocks
        assert_eq!(e.effective_shards(199), 1); // < 2 full shards
        assert_eq!(e.effective_shards(200), 2);
        assert_eq!(e.effective_shards(450), 4);
        assert_eq!(e.effective_shards(10_000), 8); // capped by threads
        assert_eq!(QuantEngine::serial().effective_shards(10_000), 1);
    }

    #[test]
    fn auto_resolves_to_at_least_one_thread() {
        assert!(QuantEngine::auto().threads() >= 1);
        assert!(QuantEngine::with_threads(0).threads() >= 1);
        assert_eq!(QuantEngine::with_threads(3).threads(), 3);
    }

    #[test]
    fn parallel_quantize_matches_serial_across_widths() {
        let h = sample_matrix(96, 32, 1); // 3072 scalars
        for bits in [2u32, 4, 8] {
            for group in [7usize, 32, 100] {
                let a = QuantEngine::serial()
                    .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                    .unwrap();
                for threads in [2usize, 5, 8] {
                    let b = QuantEngine::with_threads(threads)
                        .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                        .unwrap();
                    assert_eq!(a.packed, b.packed, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.zeros, b.zeros, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.ranges, b.ranges, "bits={bits} G={group} t={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_dequantize_matches_serial() {
        let h = sample_matrix(64, 48, 2);
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 24, 2, &BinSpec::Uniform, 5)
            .unwrap();
        let a = QuantEngine::serial().dequantize(&ct).unwrap();
        for threads in [2usize, 8] {
            let b = QuantEngine::with_threads(threads).dequantize(&ct).unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "t={threads}");
        }
    }

    #[test]
    fn vm_bins_parallel_matches_serial() {
        let h = sample_matrix(40, 16, 3);
        let bins = BinSpec::int2_vm(1.2, 1.8).unwrap();
        let a = QuantEngine::serial()
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        let b = QuantEngine::with_threads(4)
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.zeros, b.zeros);
    }

    #[test]
    fn pooled_calls_are_bit_identical_and_reuse_buffers() {
        let h = sample_matrix(32, 32, 4);
        let engine = QuantEngine::serial();
        let seed = 0xabcdu64;
        let plain = engine
            .quantize_seeded(&h, 16, 2, &BinSpec::Uniform, seed)
            .unwrap();
        let mut pool = BufferPool::new();
        let pooled = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(plain.packed, pooled.packed);
        assert_eq!(plain.zeros, pooled.zeros);
        assert_eq!(plain.ranges, pooled.ranges);
        let d1 = engine.dequantize(&pooled).unwrap();
        let d2 = engine.dequantize_pooled(&pooled, &mut pool).unwrap();
        assert_eq!(d1.as_slice(), d2.as_slice());
        // The fused codec draws only the packed output from the pool —
        // no n-byte code scratch on either side (1024 scalars at 2 bits
        // = 256 packed bytes).
        assert_eq!(pool.stats().max_byte_take, 256, "{:?}", pool.stats());
        // Recycle the consumed packed buffer like the pipeline's
        // backward pass does; the next step's packed take must then hit
        // the pool.
        pool.put_bytes(pooled.packed.clone());
        let before = pool.stats().hits;
        let again = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(again.packed, plain.packed);
        assert!(
            pool.stats().hits > before,
            "pool not reused: {:?}",
            pool.stats()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty = Matrix::zeros(0, 5);
        let ct = QuantEngine::with_threads(4)
            .quantize_seeded(&empty, 8, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.num_groups(), 0);
        assert_eq!(ct.dequantize().unwrap().shape(), (0, 5));

        let one = Matrix::from_vec(1, 1, vec![3.5]).unwrap();
        let ct = QuantEngine::with_threads(8)
            .quantize_seeded(&one, 4, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.dequantize().unwrap().as_slice(), &[3.5]);
    }

    #[test]
    fn planned_quantize_matches_serial_across_threads() {
        let h = sample_matrix(128, 32, 21); // 4096 scalars
        let mut rng = Pcg64::new(22);
        // A deliberately mixed plan: 128 blocks of 32 scalars.
        let bits: Vec<u8> = (0..128)
            .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
            .collect();
        let plan = BitPlan::new(bits, 32).unwrap();
        let reference = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xbeef)
            .unwrap();
        for threads in [2usize, 5, 8] {
            let pt = QuantEngine::with_threads(threads)
                .quantize_planned_seeded(&h, &plan, 0xbeef)
                .unwrap();
            assert_eq!(pt.packed, reference.packed, "t={threads}");
            assert_eq!(pt.zeros, reference.zeros, "t={threads}");
            assert_eq!(pt.ranges, reference.ranges, "t={threads}");
            let a = QuantEngine::serial().dequantize_planned(&reference).unwrap();
            let b = QuantEngine::with_threads(threads)
                .dequantize_planned(&pt)
                .unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "t={threads}");
        }
    }

    #[test]
    fn uniform_plan_matches_fixed_width_path_bit_exactly() {
        // A constant-width plan must reproduce the fixed-width engine
        // byte for byte: same per-block streams, same packing layout
        // (every full block is byte-aligned in both).
        let h = sample_matrix(64, 32, 23); // 2048 scalars, G=32 divides evenly
        for bits in [2u32, 4, 8] {
            let fixed = QuantEngine::serial()
                .quantize_seeded(&h, 32, bits, &BinSpec::Uniform, 77)
                .unwrap();
            let plan = BitPlan::uniform(bits, 64, 32).unwrap();
            let planned = QuantEngine::with_threads(4)
                .quantize_planned_seeded(&h, &plan, 77)
                .unwrap();
            assert_eq!(planned.packed, fixed.packed, "bits={bits}");
            assert_eq!(planned.zeros, fixed.zeros, "bits={bits}");
            assert_eq!(planned.ranges, fixed.ranges, "bits={bits}");
            let a = fixed.dequantize().unwrap();
            let b = planned.dequantize().unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "bits={bits}");
        }
    }

    #[test]
    fn planned_pooled_calls_are_bit_identical_and_reuse_buffers() {
        let h = sample_matrix(32, 32, 24);
        let plan = BitPlan::new(
            (0..64).map(|g| if g % 2 == 0 { 1u8 } else { 4 }).collect(),
            16,
        )
        .unwrap();
        let engine = QuantEngine::serial();
        let plain = engine.quantize_planned_seeded(&h, &plan, 5).unwrap();
        let mut pool = BufferPool::new();
        let pooled = engine
            .quantize_planned_impl(&h, &plan, 5, Some(&mut pool))
            .unwrap();
        assert_eq!(plain.packed, pooled.packed);
        assert_eq!(plain.zeros, pooled.zeros);
        let d1 = engine.dequantize_planned(&pooled).unwrap();
        let d2 = engine.dequantize_planned_pooled(&pooled, &mut pool).unwrap();
        assert_eq!(d1.as_slice(), d2.as_slice());
        // Recycle the consumed packed buffer like the pipeline's backward
        // pass does; the next step's packed take must then hit the pool.
        pool.put_bytes(pooled.packed.clone());
        let before = pool.stats().hits;
        let again = engine
            .quantize_planned_impl(&h, &plan, 5, Some(&mut pool))
            .unwrap();
        assert_eq!(again.packed, plain.packed);
        assert!(pool.stats().hits > before, "pool not reused");
    }

    #[test]
    fn planned_error_bounded_by_block_width() {
        // |ĥ - h| <= range_g / (2^{b_g} - 1) for each block's own width.
        let h = sample_matrix(16, 32, 25);
        let bits: Vec<u8> = (0..32).map(|g| [1u8, 2, 4, 8][g % 4]).collect();
        let plan = BitPlan::new(bits, 16).unwrap();
        let pt = QuantEngine::with_threads(3)
            .quantize_planned_seeded(&h, &plan, 9)
            .unwrap();
        let d = pt.dequantize().unwrap();
        for (idx, (&orig, &deq)) in h.as_slice().iter().zip(d.as_slice()).enumerate() {
            let g = idx / 16;
            let b = ((1u32 << plan.bit(g)) - 1) as f32;
            let width = pt.ranges[g] / b;
            assert!(
                (orig - deq).abs() <= width * 1.0001,
                "idx={idx} bits={}: |{orig} - {deq}| > {width}",
                plan.bit(g)
            );
        }
    }

    #[test]
    fn planned_handles_ragged_and_empty() {
        // 1221 scalars, G=100 -> 13 blocks, last has 21 scalars.
        let h = sample_matrix(33, 37, 26);
        let bits: Vec<u8> = (0..13).map(|g| [2u8, 8][g % 2]).collect();
        let plan = BitPlan::new(bits, 100).unwrap();
        let a = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 31)
            .unwrap();
        let b = QuantEngine::with_threads(8)
            .quantize_planned_seeded(&h, &plan, 31)
            .unwrap();
        assert_eq!(a.packed, b.packed);
        assert_eq!(
            a.dequantize().unwrap().as_slice(),
            b.dequantize().unwrap().as_slice()
        );

        let empty = Matrix::zeros(0, 7);
        let plan = BitPlan::new(vec![], 8).unwrap();
        let pt = QuantEngine::with_threads(4)
            .quantize_planned_seeded(&empty, &plan, 1)
            .unwrap();
        assert_eq!(pt.num_groups(), 0);
        assert_eq!(pt.dequantize().unwrap().shape(), (0, 7));
    }

    #[test]
    fn planned_rejects_mismatched_plan() {
        let h = sample_matrix(8, 8, 27);
        // 64 scalars at G=16 need 4 blocks; give 3.
        let plan = BitPlan::new(vec![2, 2, 2], 16).unwrap();
        assert!(QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 1)
            .is_err());
        // Malformed planned tensor: truncated packed buffer.
        let good_plan = BitPlan::new(vec![2, 2, 2, 2], 16).unwrap();
        let mut pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &good_plan, 1)
            .unwrap();
        pt.packed.truncate(3);
        assert!(QuantEngine::serial().dequantize_planned(&pt).is_err());
        let mut pt2 = QuantEngine::serial()
            .quantize_planned_seeded(&h, &good_plan, 1)
            .unwrap();
        pt2.zeros.pop();
        assert!(QuantEngine::serial().dequantize_planned(&pt2).is_err());
    }

    #[test]
    fn dequantize_rejects_malformed_tensors() {
        let h = sample_matrix(8, 8, 5);
        let good = QuantEngine::serial()
            .quantize_seeded(&h, 8, 2, &BinSpec::Uniform, 2)
            .unwrap();
        let mut short = good.clone();
        short.packed.truncate(1);
        assert!(QuantEngine::serial().dequantize(&short).is_err());
        let mut missing_meta = good.clone();
        missing_meta.zeros.pop();
        assert!(QuantEngine::serial().dequantize(&missing_meta).is_err());
        let mut bad_bits = good;
        bad_bits.bits = 3;
        assert!(QuantEngine::serial().dequantize(&bad_bits).is_err());
    }

    fn ring_adjacency(n: usize) -> crate::graph::CsrMatrix {
        // Ring + a few chords so rows reference blocks non-contiguously.
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i, (i + 1) % n, 0.5f32));
            edges.push((i, (i + 7) % n, 0.25f32));
            edges.push((i, i, 1.0f32));
        }
        crate::graph::CsrMatrix::from_edges(n, &edges).unwrap()
    }

    #[test]
    fn fused_matmul_matches_materialize_bitwise() {
        // Fixed-width stash (uniform and VM bins): fused decode→matmul
        // must equal dequantize-then-matmul byte for byte, at any thread
        // count. G = 32 scalars = 2 rows of 16, so blocks are
        // row-aligned and the streaming path engages.
        let h = sample_matrix(48, 16, 31);
        let b = sample_matrix(16, 24, 32);
        for bins in [BinSpec::Uniform, BinSpec::int2_vm(1.2, 1.8).unwrap()] {
            let ct = QuantEngine::serial()
                .quantize_seeded(&h, 32, 2, &bins, 5)
                .unwrap();
            let reference = QuantEngine::serial()
                .dequantize(&ct)
                .unwrap()
                .matmul(&b)
                .unwrap();
            for threads in [1usize, 2, 4, 7] {
                let e = QuantEngine::with_threads(threads);
                let mut pool = BufferPool::new();
                let fused = e.dequantize_matmul(&ct, &b, &mut pool).unwrap();
                assert_eq!(fused.as_slice(), reference.as_slice(), "t={threads}");
                // Scratch stayed tile-sized: one block per worker, never
                // the full 48x16 dense intermediate.
                assert!(
                    pool.stats().max_float_take <= 32,
                    "fused path took {} floats",
                    pool.stats().max_float_take
                );
            }
        }
    }

    #[test]
    fn fused_matmul_planned_matches_materialize_bitwise() {
        let h = sample_matrix(64, 16, 33); // 1024 scalars
        let b = sample_matrix(16, 8, 34);
        let mut rng = Pcg64::new(35);
        // 32 blocks of 32 scalars (2 rows each), mixed widths.
        let bits: Vec<u8> = (0..32)
            .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
            .collect();
        let plan = BitPlan::new(bits, 32).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xfeed)
            .unwrap();
        let reference = QuantEngine::serial()
            .dequantize_planned(&pt)
            .unwrap()
            .matmul(&b)
            .unwrap();
        for threads in [1usize, 2, 4, 7] {
            let e = QuantEngine::with_threads(threads);
            let mut pool = BufferPool::new();
            let fused = e.dequantize_matmul_planned(&pt, &b, &mut pool).unwrap();
            assert_eq!(fused.as_slice(), reference.as_slice(), "t={threads}");
            assert!(pool.stats().max_float_take <= 32);
        }
    }

    #[test]
    fn fused_spmm_planned_matches_materialize_bitwise() {
        let n = 60;
        let h = sample_matrix(n, 16, 36);
        let adj = ring_adjacency(n);
        let mut rng = Pcg64::new(37);
        // 30 blocks of 32 scalars (2 rows each), mixed widths.
        let bits: Vec<u8> = (0..30)
            .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
            .collect();
        let plan = BitPlan::new(bits, 32).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xabba)
            .unwrap();
        let reference = adj
            .spmm(&QuantEngine::serial().dequantize_planned(&pt).unwrap())
            .unwrap();
        for threads in [1usize, 2, 4, 7] {
            let e = QuantEngine::with_threads(threads);
            let mut pool = BufferPool::new();
            let fused = e.dequantize_spmm_planned(&adj, &pt, &mut pool).unwrap();
            assert_eq!(fused.as_slice(), reference.as_slice(), "t={threads}");
            // One decoded block per worker, never the dense 60x16 matrix.
            assert!(
                pool.stats().max_float_take <= 32,
                "fused spmm took {} floats",
                pool.stats().max_float_take
            );
        }
    }

    #[test]
    fn fused_kernels_fall_back_on_unaligned_blocks() {
        // G = 24 does not divide the row width 16, so blocks straddle
        // rows; the fused entry points must still return the exact
        // materialize-then-aggregate result (via the fallback).
        let h = sample_matrix(30, 16, 38);
        let b = sample_matrix(16, 4, 39);
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 24, 4, &BinSpec::Uniform, 6)
            .unwrap();
        let engine = QuantEngine::with_threads(3);
        let mut pool = BufferPool::new();
        let fused = engine.dequantize_matmul(&ct, &b, &mut pool).unwrap();
        let reference = engine.dequantize(&ct).unwrap().matmul(&b).unwrap();
        assert_eq!(fused.as_slice(), reference.as_slice());

        let plan = BitPlan::uniform(4, 20, 24).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 7)
            .unwrap();
        let adj = ring_adjacency(30);
        let fused = engine.dequantize_spmm_planned(&adj, &pt, &mut pool).unwrap();
        let reference = adj
            .spmm(&engine.dequantize_planned(&pt).unwrap())
            .unwrap();
        assert_eq!(fused.as_slice(), reference.as_slice());
        let fused = engine.dequantize_matmul_planned(&pt, &b, &mut pool).unwrap();
        let reference = engine.dequantize_planned(&pt).unwrap().matmul(&b).unwrap();
        assert_eq!(fused.as_slice(), reference.as_slice());
    }

    #[test]
    fn fused_kernels_validate_shapes() {
        let h = sample_matrix(8, 8, 40);
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 8, 2, &BinSpec::Uniform, 8)
            .unwrap();
        let engine = QuantEngine::serial();
        let mut pool = BufferPool::new();
        // Contraction-dim mismatch.
        assert!(engine
            .dequantize_matmul(&ct, &Matrix::zeros(9, 3), &mut pool)
            .is_err());
        // Malformed tensor.
        let mut bad = ct.clone();
        bad.packed.truncate(1);
        assert!(engine
            .dequantize_matmul(&bad, &Matrix::zeros(8, 3), &mut pool)
            .is_err());
        // Planned: adjacency width mismatch.
        let plan = BitPlan::uniform(2, 8, 8).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 9)
            .unwrap();
        let adj = ring_adjacency(9);
        assert!(engine.dequantize_spmm_planned(&adj, &pt, &mut pool).is_err());
        let mut bad = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 9)
            .unwrap();
        bad.zeros.pop();
        assert!(engine
            .dequantize_matmul_planned(&bad, &Matrix::zeros(8, 3), &mut pool)
            .is_err());
    }

    #[test]
    fn touched_row_decode_matches_full_dequantize_bitwise() {
        // The serving read path: decoding only the requested rows must
        // equal gathering the same rows from the full decode, byte for
        // byte, at any thread count — with one block of scratch per
        // worker, never the dense matrix.
        let n = 64;
        let h = sample_matrix(n, 16, 50);
        let mut rng = Pcg64::new(51);
        // 16 blocks of 64 scalars (4 rows each), mixed widths.
        let bits: Vec<u8> = (0..16)
            .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
            .collect();
        let plan = BitPlan::new(bits, 64).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xcafe)
            .unwrap();
        let full = QuantEngine::serial().dequantize_planned(&pt).unwrap();
        let rows: Vec<usize> = vec![0, 3, 3, 17, 62, 5, 63, 0];
        for threads in [1usize, 2, 4, 7] {
            let e = QuantEngine::with_threads(threads);
            let mut pool = BufferPool::new();
            let got = e.dequantize_rows_planned(&pt, &rows, &mut pool).unwrap();
            assert_eq!(got.shape(), (rows.len(), 16));
            for (i, &r) in rows.iter().enumerate() {
                assert_eq!(
                    &got.as_slice()[i * 16..(i + 1) * 16],
                    &full.as_slice()[r * 16..(r + 1) * 16],
                    "t={threads} row {r}"
                );
            }
            assert!(
                pool.stats().max_float_take <= 64,
                "touched-row decode took {} floats",
                pool.stats().max_float_take
            );
        }
    }

    #[test]
    fn decode_blocks_planned_matches_full_decode() {
        let h = sample_matrix(32, 16, 52); // 512 scalars, 8 blocks of 64
        let plan = BitPlan::new(vec![2, 4, 1, 8, 2, 2, 4, 1], 64).unwrap();
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xd00d)
            .unwrap();
        let full = QuantEngine::serial().dequantize_planned(&pt).unwrap();
        let blocks = vec![7usize, 0, 3, 3, 5];
        for threads in [1usize, 3, 8] {
            let e = QuantEngine::with_threads(threads);
            let mut arena = vec![0f32; blocks.len() * 64];
            e.decode_blocks_planned(&pt, &blocks, &mut arena).unwrap();
            for (i, &g) in blocks.iter().enumerate() {
                assert_eq!(
                    &arena[i * 64..(i + 1) * 64],
                    &full.as_slice()[g * 64..(g + 1) * 64],
                    "t={threads} block {g}"
                );
            }
        }
        // Bounds errors are named, never panics.
        let e = QuantEngine::serial();
        let msg = e
            .decode_blocks_planned(&pt, &[8], &mut vec![0f32; 64])
            .unwrap_err()
            .to_string();
        assert!(msg.contains("out of range"), "{msg}");
        let msg = e
            .decode_blocks_planned(&pt, &[0, 1], &mut vec![0f32; 64])
            .unwrap_err()
            .to_string();
        assert!(msg.contains("output holds"), "{msg}");
    }

    #[test]
    fn touched_row_spmm_matches_full_product_bitwise() {
        let n = 60;
        let h = sample_matrix(n, 16, 53);
        let adj = ring_adjacency(n);
        let plan = BitPlan::uniform(2, 30, 32).unwrap(); // 2 rows per block
        let pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xf00f)
            .unwrap();
        let reference = adj
            .spmm(&QuantEngine::serial().dequantize_planned(&pt).unwrap())
            .unwrap();
        let out_rows: Vec<usize> = vec![0, 59, 13, 13, 28, 7];
        for threads in [1usize, 2, 5] {
            let e = QuantEngine::with_threads(threads);
            let mut pool = BufferPool::new();
            let got = e
                .dequantize_spmm_rows_planned(&adj, &pt, &out_rows, &mut pool)
                .unwrap();
            for (i, &r) in out_rows.iter().enumerate() {
                assert_eq!(
                    &got.as_slice()[i * 16..(i + 1) * 16],
                    &reference.as_slice()[r * 16..(r + 1) * 16],
                    "t={threads} row {r}"
                );
            }
            assert!(pool.stats().max_float_take <= 32);
        }
    }

    #[test]
    fn touched_row_entry_points_reject_bad_inputs() {
        let h = sample_matrix(30, 16, 54);
        let engine = QuantEngine::serial();
        let mut pool = BufferPool::new();
        // Non-row-aligned plan: named Config error, no silent dense
        // fallback on the serving path.
        let plan = BitPlan::uniform(4, 20, 24).unwrap();
        let pt = engine.quantize_planned_seeded(&h, &plan, 1).unwrap();
        let msg = engine
            .dequantize_rows_planned(&pt, &[0], &mut pool)
            .unwrap_err()
            .to_string();
        assert!(msg.contains("row-aligned"), "{msg}");
        let adj = ring_adjacency(30);
        let msg = engine
            .dequantize_spmm_rows_planned(&adj, &pt, &[0], &mut pool)
            .unwrap_err()
            .to_string();
        assert!(msg.contains("row-aligned"), "{msg}");
        // Out-of-range indices on an aligned plan.
        let plan = BitPlan::uniform(2, 15, 32).unwrap();
        let pt = engine.quantize_planned_seeded(&h, &plan, 2).unwrap();
        let msg = engine
            .dequantize_rows_planned(&pt, &[30], &mut pool)
            .unwrap_err()
            .to_string();
        assert!(msg.contains("out of range"), "{msg}");
        let msg = engine
            .dequantize_spmm_rows_planned(&adj, &pt, &[99], &mut pool)
            .unwrap_err()
            .to_string();
        assert!(msg.contains("out of range"), "{msg}");
        // Empty queries are fine.
        assert_eq!(
            engine
                .dequantize_rows_planned(&pt, &[], &mut pool)
                .unwrap()
                .shape(),
            (0, 16)
        );
    }

    #[test]
    fn engine_reuses_one_pool_across_calls() {
        // The persistent pool is shared by clones and reused across
        // calls — no per-call spawning (the ISSUE 4 tentpole).
        let engine = QuantEngine::with_threads(4);
        assert_eq!(engine.threads(), 4);
        let clone = engine.clone();
        assert!(Arc::ptr_eq(engine.runtime(), clone.runtime()));
        let shared = QuantEngine::with_runtime(Arc::clone(engine.runtime()), 1);
        assert!(Arc::ptr_eq(engine.runtime(), shared.runtime()));
        assert_eq!(engine, shared);
        let h = sample_matrix(64, 32, 41);
        let a = engine
            .quantize_seeded(&h, 16, 2, &BinSpec::Uniform, 3)
            .unwrap();
        let b = shared
            .quantize_seeded(&h, 16, 2, &BinSpec::Uniform, 3)
            .unwrap();
        assert_eq!(a.packed, b.packed);
    }
}
