//! Multi-threaded quantization execution engine.
//!
//! The independent-blocks structure of Eq. 6 makes every quantization
//! group — one `(zero-point, range)` pair plus its slice of codes —
//! embarrassingly parallel, which is exactly what ActNN and GACT exploit
//! for throughput. [`QuantEngine`] shards the flat block list of
//! [`BlockwiseQuantizer`](crate::quant::BlockwiseQuantizer) (and the
//! per-row groups of [`RowQuantizer`](crate::quant::RowQuantizer)) into
//! contiguous per-thread shards driven by `std::thread::scope`.
//!
//! ## Determinism
//!
//! Block `g` always draws its stochastic-rounding randomness from the
//! deterministic stream [`Pcg64::with_stream`]`(seed, g)` — the stream
//! assignment depends only on the block *index*, never on which worker
//! processes it or how many workers exist. Parallel output is therefore
//! **bit-identical to serial** for the same seed, at every bit width and
//! any thread count:
//!
//! ```
//! use iexact::engine::QuantEngine;
//! use iexact::quant::BinSpec;
//! use iexact::rngs::Pcg64;
//! use iexact::tensor::Matrix;
//!
//! let mut rng = Pcg64::new(7);
//! let h = Matrix::from_fn(64, 32, |_, _| rng.next_f32());
//! let serial = QuantEngine::serial()
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! let parallel = QuantEngine::with_threads(4)
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! assert_eq!(serial.packed, parallel.packed);
//! assert_eq!(serial.zeros, parallel.zeros);
//! ```
//!
//! ## Configuration
//!
//! Production code builds the engine from the `[parallelism]` config
//! section via [`QuantEngine::from_config`]; see
//! [`ParallelismConfig`](crate::config::ParallelismConfig) for the
//! thread-count and shard-granularity knobs and the auto heuristic.

use crate::alloc::{BitPlan, PlannedTensor};
use crate::config::ParallelismConfig;
use crate::memory::BufferPool;
use crate::quant::{
    dequantize_block, pack_codes_into, pack_codes_slice, quantize_block, unpack_range, BinSpec,
    CompressedTensor, DequantPlan, QuantPlan,
};
use crate::rngs::Pcg64;
use crate::tensor::Matrix;
use crate::{Error, Result};

/// Slot in a per-width lookup array for the supported widths 1/2/4/8
/// (1 → 0, 2 → 1, 4 → 2, 8 → 3).
#[inline]
fn width_slot(bits: u32) -> usize {
    bits.trailing_zeros() as usize
}

/// Auto mode caps the worker count here: grouped quantization saturates
/// memory bandwidth well before it saturates very wide machines, and the
/// per-call `thread::scope` spawn cost grows with the worker count.
pub const MAX_AUTO_THREADS: usize = 8;

/// Resolve a configured thread count (`0` = auto) to a concrete one.
fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_AUTO_THREADS)
    }
}

/// Sharded executor for grouped quantize/dequantize.
///
/// Cheap to construct and `Clone`; holds no threads — workers are scoped
/// per call, so the engine can be shared freely across the pipeline,
/// coordinator and benches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantEngine {
    threads: usize,
    min_blocks_per_shard: usize,
}

impl QuantEngine {
    /// Single-threaded engine — the reference every parallel result is
    /// bit-compared against.
    pub fn serial() -> Self {
        QuantEngine {
            threads: 1,
            min_blocks_per_shard: 1,
        }
    }

    /// Engine with an explicit worker count (`0` = auto-detect). Shard
    /// gating is disabled (`min_blocks_per_shard = 1`) so even small
    /// inputs fan out — the right default for tests and benches;
    /// production configs go through [`Self::from_config`].
    pub fn with_threads(threads: usize) -> Self {
        QuantEngine {
            threads: resolve_threads(threads),
            min_blocks_per_shard: 1,
        }
    }

    /// Engine for the default [`ParallelismConfig`]: auto thread count,
    /// production shard gating.
    pub fn auto() -> Self {
        Self::from_config(&ParallelismConfig::default())
    }

    /// Build from the `[parallelism]` config section, resolving auto mode
    /// against `std::thread::available_parallelism`.
    pub fn from_config(cfg: &ParallelismConfig) -> Self {
        QuantEngine {
            threads: resolve_threads(cfg.threads),
            min_blocks_per_shard: cfg.min_blocks_per_shard.max(1),
        }
    }

    /// Resolved worker-count ceiling for this engine.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Worker count actually used for `num_blocks` independent blocks:
    /// stays serial until at least two shards of `min_blocks_per_shard`
    /// blocks exist (fan-out below that loses more to spawn overhead than
    /// it gains), then grows linearly and caps at the configured thread
    /// count.
    pub fn effective_shards(&self, num_blocks: usize) -> usize {
        if self.threads <= 1 {
            return 1;
        }
        if num_blocks < self.min_blocks_per_shard.saturating_mul(2) {
            return 1;
        }
        self.threads.min(num_blocks / self.min_blocks_per_shard).max(1)
    }

    /// Grouped quantization (Eq. 2 + Eq. 6) with randomness drawn from
    /// `rng`: one `u64` draw keys the per-block streams, so the caller's
    /// generator advances identically regardless of thread count.
    pub fn quantize(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
    ) -> Result<CompressedTensor> {
        self.quantize_seeded(h, group_len, bits, bins, rng.next_u64())
    }

    /// Seed-addressed grouped quantization. Bit-identical across engines:
    /// `serial().quantize_seeded(..)` ==
    /// `with_threads(n).quantize_seeded(..)` for every `n`.
    pub fn quantize_seeded(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, seed, None)
    }

    /// [`Self::quantize`] with scratch and output buffers recycled
    /// through `pool` — the packed buffer comes from the pool and the
    /// code scratch returns to it, so steady-state training does no
    /// per-layer allocation for the compressed path.
    pub fn quantize_pooled(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
        pool: &mut BufferPool,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, rng.next_u64(), Some(pool))
    }

    fn quantize_impl(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<CompressedTensor> {
        let plan = QuantPlan::resolve(bits, bins, group_len)?;
        let data = h.as_slice();
        let n = data.len();
        let num_groups = n.div_ceil(group_len);

        // Scratch contents are unspecified: quantize_block writes every
        // element of each block (including the constant-block fill).
        let mut codes = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_scratch(n),
            None => vec![0u8; n],
        };
        let mut zeros = vec![0f32; num_groups];
        let mut ranges = vec![0f32; num_groups];

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                let mut rng_g = Pcg64::with_stream(seed, g as u64);
                let (z, r) =
                    quantize_block(&plan, &data[start..end], &mut codes[start..end], &mut rng_g);
                zeros[g] = z;
                ranges[g] = r;
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let plan = &plan;
            std::thread::scope(|s| {
                for (idx, (((data_c, codes_c), zeros_c), ranges_c)) in data
                    .chunks(chunk)
                    .zip(codes.chunks_mut(chunk))
                    .zip(zeros.chunks_mut(groups_per_shard))
                    .zip(ranges.chunks_mut(groups_per_shard))
                    .enumerate()
                {
                    let base = idx * groups_per_shard;
                    s.spawn(move || {
                        for (j, (z, r)) in
                            zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                        {
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(data_c.len());
                            let mut rng_g = Pcg64::with_stream(seed, (base + j) as u64);
                            let (zz, rr) = quantize_block(
                                plan,
                                &data_c[lo..hi],
                                &mut codes_c[lo..hi],
                                &mut rng_g,
                            );
                            *z = zz;
                            *r = rr;
                        }
                    });
                }
            });
        }

        let mut packed = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_empty((n * bits as usize).div_ceil(8)),
            None => Vec::new(),
        };
        pack_codes_into(&codes, bits, &mut packed)?;
        if let Some(p) = pool.as_deref_mut() {
            p.put_bytes(codes);
        }
        Ok(CompressedTensor {
            packed,
            zeros,
            ranges,
            shape: h.shape(),
            group_len,
            bits,
            bins: bins.clone(),
        })
    }

    /// Dequantize (Eq. 3), sharding the group loop across worker threads.
    /// Purely deterministic, so parallel and serial results are
    /// bit-identical by construction.
    pub fn dequantize(&self, ct: &CompressedTensor) -> Result<Matrix> {
        self.dequantize_impl(ct, None)
    }

    /// [`Self::dequantize`] with the output and code-scratch buffers
    /// drawn from (and returned to) `pool`.
    pub fn dequantize_pooled(
        &self,
        ct: &CompressedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        self.dequantize_impl(ct, Some(pool))
    }

    fn dequantize_impl(
        &self,
        ct: &CompressedTensor,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<Matrix> {
        if !matches!(ct.bits, 1 | 2 | 4 | 8) {
            return Err(Error::Config(format!("unsupported bit width {}", ct.bits)));
        }
        if ct.group_len == 0 {
            return Err(Error::Config("group_len must be positive".into()));
        }
        let (rows, cols) = ct.shape;
        let n = rows * cols;
        let num_groups = n.div_ceil(ct.group_len);
        let codes_per_byte = (8 / ct.bits) as usize;
        if ct.packed.len() * codes_per_byte < n {
            return Err(Error::Shape(format!(
                "packed buffer too short: wanted {n} codes, got {}",
                ct.packed.len() * codes_per_byte
            )));
        }
        if ct.zeros.len() != num_groups || ct.ranges.len() != num_groups {
            return Err(Error::Shape(format!(
                "expected {num_groups} (zero, range) pairs, got ({}, {})",
                ct.zeros.len(),
                ct.ranges.len()
            )));
        }
        let plan = DequantPlan::resolve(ct.bits, &ct.bins);
        let group_len = ct.group_len;
        // Every element of `out` (and the unpack scratch) is overwritten
        // group by group, so unspecified-content takes are safe.
        let mut out = match pool.as_deref_mut() {
            Some(p) => p.take_floats_scratch(n),
            None => vec![0f32; n],
        };

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            let mut scratch = match pool.as_deref_mut() {
                Some(p) => p.take_bytes_scratch(n),
                None => vec![0u8; n],
            };
            unpack_range(&ct.packed, ct.bits, 0, &mut scratch);
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                dequantize_block(
                    &plan,
                    ct.zeros[g],
                    ct.ranges[g],
                    &scratch[start..end],
                    &mut out[start..end],
                );
            }
            if let Some(p) = pool.as_deref_mut() {
                p.put_bytes(scratch);
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let shard_count = num_groups.div_ceil(groups_per_shard);
            // Per-shard unpack scratch, drawn from the pool up front so
            // the steady-state parallel path stays allocation-free too.
            let mut scratches: Vec<Vec<u8>> = (0..shard_count)
                .map(|i| {
                    let len = chunk.min(n - i * chunk);
                    match pool.as_deref_mut() {
                        Some(p) => p.take_bytes_scratch(len),
                        None => vec![0u8; len],
                    }
                })
                .collect();
            let plan = &plan;
            let packed = ct.packed.as_slice();
            let zeros = ct.zeros.as_slice();
            let ranges = ct.ranges.as_slice();
            let bits = ct.bits;
            std::thread::scope(|s| {
                for (idx, (((out_c, zeros_c), ranges_c), scratch)) in out
                    .chunks_mut(chunk)
                    .zip(zeros.chunks(groups_per_shard))
                    .zip(ranges.chunks(groups_per_shard))
                    .zip(scratches.iter_mut())
                    .enumerate()
                {
                    s.spawn(move || {
                        // Each shard unpacks only its own scalar range —
                        // in-bounds by the packed-length check above.
                        unpack_range(packed, bits, idx * chunk, scratch);
                        for (j, (&z, &r)) in zeros_c.iter().zip(ranges_c).enumerate() {
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(out_c.len());
                            dequantize_block(
                                plan,
                                z,
                                r,
                                &scratch[lo..hi],
                                &mut out_c[lo..hi],
                            );
                        }
                    });
                }
            });
            if let Some(p) = pool.as_deref_mut() {
                for scratch in scratches {
                    p.put_bytes(scratch);
                }
            }
        }
        Matrix::from_vec(rows, cols, out)
    }

    /// Grouped quantization under a heterogeneous [`BitPlan`]: block `g`
    /// is quantized at `plan.bit(g)` with uniform bins, packed
    /// byte-aligned at `plan.offsets(n)[g]`. One `u64` draw from `rng`
    /// keys the per-block streams, exactly like [`Self::quantize`].
    ///
    /// ```
    /// use iexact::alloc::BitPlan;
    /// use iexact::engine::QuantEngine;
    /// use iexact::rngs::Pcg64;
    /// use iexact::tensor::Matrix;
    ///
    /// let mut rng = Pcg64::new(3);
    /// let h = Matrix::from_fn(4, 16, |_, _| rng.next_f32());
    /// // 4 blocks of 16 scalars at 1/2/4/8 bits.
    /// let plan = BitPlan::new(vec![1, 2, 4, 8], 16).unwrap();
    /// let pt = QuantEngine::serial().quantize_planned(&h, &plan, &mut rng).unwrap();
    /// assert_eq!(pt.num_groups(), 4);
    /// assert_eq!(pt.packed.len(), 2 + 4 + 8 + 16);
    /// assert_eq!(pt.dequantize().unwrap().shape(), (4, 16));
    /// ```
    pub fn quantize_planned(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        rng: &mut Pcg64,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_seeded(h, plan, rng.next_u64())
    }

    /// Seed-addressed planned quantization — bit-identical across
    /// engines for every `BitPlan`, like [`Self::quantize_seeded`].
    pub fn quantize_planned_seeded(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, seed, None)
    }

    /// [`Self::quantize_planned`] with the packed buffer and code scratch
    /// recycled through `pool`.
    pub fn quantize_planned_pooled(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        rng: &mut Pcg64,
        pool: &mut BufferPool,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, rng.next_u64(), Some(pool))
    }

    /// Seed-addressed **and** pooled planned quantization: the
    /// idempotent entry point behind
    /// [`ActivationCache::park`](crate::memory::ActivationCache::park) —
    /// re-quantizing the same matrix under the same seed reproduces the
    /// same bytes while still recycling buffers through `pool`.
    pub fn quantize_planned_seeded_pooled(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
        pool: &mut BufferPool,
    ) -> Result<PlannedTensor> {
        self.quantize_planned_impl(h, plan, seed, Some(pool))
    }

    fn quantize_planned_impl(
        &self,
        h: &Matrix,
        plan: &BitPlan,
        seed: u64,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<PlannedTensor> {
        let data = h.as_slice();
        let n = data.len();
        let group_len = plan.group_len();
        let num_groups = plan.num_blocks();
        let offsets = plan.offsets(n)?; // also validates plan coverage
        let total_bytes = *offsets.last().expect("offsets non-empty");

        // Resolve one fixed-width QuantPlan per width the plan uses —
        // all with uniform bins (the VM bin layout is INT2-specific and
        // belongs to the fixed-width RowWiseVm mode).
        let mut qplans: [Option<QuantPlan>; 4] = [None, None, None, None];
        for &b in plan.bits() {
            let slot = width_slot(b as u32);
            if qplans[slot].is_none() {
                qplans[slot] = Some(QuantPlan::resolve(b as u32, &BinSpec::Uniform, group_len)?);
            }
        }

        let mut zeros = vec![0f32; num_groups];
        let mut ranges = vec![0f32; num_groups];
        // Every byte of `packed` is written by pack_codes_slice (blocks
        // are byte-aligned, partial final bytes zero-padded), so an
        // unspecified-content take is safe.
        let mut packed = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_scratch(total_bytes),
            None => vec![0u8; total_bytes],
        };

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            let mut scratch = match pool.as_deref_mut() {
                Some(p) => p.take_bytes_scratch(group_len.min(n.max(1))),
                None => vec![0u8; group_len.min(n.max(1))],
            };
            for g in 0..num_groups {
                let lo = g * group_len;
                let hi = (lo + group_len).min(n);
                let bits = plan.bit(g);
                let qp = qplans[width_slot(bits)].as_ref().expect("resolved above");
                let mut rng_g = Pcg64::with_stream(seed, g as u64);
                let (z, r) =
                    quantize_block(qp, &data[lo..hi], &mut scratch[..hi - lo], &mut rng_g);
                zeros[g] = z;
                ranges[g] = r;
                pack_codes_slice(
                    &scratch[..hi - lo],
                    bits,
                    &mut packed[offsets[g]..offsets[g + 1]],
                );
            }
            if let Some(p) = pool.as_deref_mut() {
                p.put_bytes(scratch);
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let shard_count = num_groups.div_ceil(groups_per_shard);
            // Split the packed buffer at shard boundaries (blocks are
            // byte-aligned, so shard ranges are disjoint byte ranges).
            let mut packed_chunks: Vec<&mut [u8]> = Vec::with_capacity(shard_count);
            let mut rest: &mut [u8] = packed.as_mut_slice();
            let mut consumed = 0usize;
            for i in 0..shard_count {
                let end = offsets[((i + 1) * groups_per_shard).min(num_groups)];
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(end - consumed);
                packed_chunks.push(head);
                rest = tail;
                consumed = end;
            }
            let offsets = offsets.as_slice();
            let qplans = &qplans;
            std::thread::scope(|s| {
                for (i, ((packed_c, zeros_c), ranges_c)) in packed_chunks
                    .into_iter()
                    .zip(zeros.chunks_mut(groups_per_shard))
                    .zip(ranges.chunks_mut(groups_per_shard))
                    .enumerate()
                {
                    s.spawn(move || {
                        let base = i * groups_per_shard;
                        let base_off = offsets[base];
                        let mut scratch = vec![0u8; group_len];
                        for (j, (z, r)) in
                            zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                        {
                            let g = base + j;
                            let lo = g * group_len;
                            let hi = (lo + group_len).min(n);
                            let bits = plan.bit(g);
                            let qp =
                                qplans[width_slot(bits)].as_ref().expect("resolved above");
                            let mut rng_g = Pcg64::with_stream(seed, g as u64);
                            let (zz, rr) = quantize_block(
                                qp,
                                &data[lo..hi],
                                &mut scratch[..hi - lo],
                                &mut rng_g,
                            );
                            *z = zz;
                            *r = rr;
                            pack_codes_slice(
                                &scratch[..hi - lo],
                                bits,
                                &mut packed_c[offsets[g] - base_off..offsets[g + 1] - base_off],
                            );
                        }
                    });
                }
            });
        }

        Ok(PlannedTensor {
            packed,
            zeros,
            ranges,
            shape: h.shape(),
            plan: plan.clone(),
        })
    }

    /// Dequantize a [`PlannedTensor`] (Eq. 3 per block, each at its own
    /// width), sharding the block loop across worker threads. Purely
    /// deterministic — parallel and serial results are bit-identical.
    pub fn dequantize_planned(&self, pt: &PlannedTensor) -> Result<Matrix> {
        self.dequantize_planned_impl(pt, None)
    }

    /// [`Self::dequantize_planned`] with the output and unpack scratch
    /// drawn from (and returned to) `pool`.
    pub fn dequantize_planned_pooled(
        &self,
        pt: &PlannedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        self.dequantize_planned_impl(pt, Some(pool))
    }

    fn dequantize_planned_impl(
        &self,
        pt: &PlannedTensor,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<Matrix> {
        let (rows, cols) = pt.shape;
        let n = rows * cols;
        let group_len = pt.plan.group_len();
        let num_groups = pt.plan.num_blocks();
        let offsets = pt.plan.offsets(n)?;
        let total_bytes = *offsets.last().expect("offsets non-empty");
        if pt.packed.len() < total_bytes {
            return Err(Error::Shape(format!(
                "packed buffer too short: plan needs {total_bytes} bytes, got {}",
                pt.packed.len()
            )));
        }
        if pt.zeros.len() != num_groups || pt.ranges.len() != num_groups {
            return Err(Error::Shape(format!(
                "expected {num_groups} (zero, range) pairs, got ({}, {})",
                pt.zeros.len(),
                pt.ranges.len()
            )));
        }
        let mut dplans: [Option<DequantPlan>; 4] = [None, None, None, None];
        for &b in pt.plan.bits() {
            let slot = width_slot(b as u32);
            if dplans[slot].is_none() {
                dplans[slot] = Some(DequantPlan::resolve(b as u32, &BinSpec::Uniform));
            }
        }
        let mut out = match pool.as_deref_mut() {
            Some(p) => p.take_floats_scratch(n),
            None => vec![0f32; n],
        };

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            let mut scratch = match pool.as_deref_mut() {
                Some(p) => p.take_bytes_scratch(group_len.min(n.max(1))),
                None => vec![0u8; group_len.min(n.max(1))],
            };
            for g in 0..num_groups {
                let lo = g * group_len;
                let hi = (lo + group_len).min(n);
                let bits = pt.plan.bit(g);
                let dp = dplans[width_slot(bits)].as_ref().expect("resolved above");
                unpack_range(
                    &pt.packed[offsets[g]..offsets[g + 1]],
                    bits,
                    0,
                    &mut scratch[..hi - lo],
                );
                dequantize_block(
                    dp,
                    pt.zeros[g],
                    pt.ranges[g],
                    &scratch[..hi - lo],
                    &mut out[lo..hi],
                );
            }
            if let Some(p) = pool.as_deref_mut() {
                p.put_bytes(scratch);
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let offsets = offsets.as_slice();
            let dplans = &dplans;
            let packed = pt.packed.as_slice();
            let zeros = pt.zeros.as_slice();
            let ranges = pt.ranges.as_slice();
            let plan = &pt.plan;
            std::thread::scope(|s| {
                for (i, out_c) in out.chunks_mut(chunk).enumerate() {
                    s.spawn(move || {
                        let base = i * groups_per_shard;
                        let mut scratch = vec![0u8; group_len];
                        let blocks = out_c.len().div_ceil(group_len);
                        for j in 0..blocks {
                            let g = base + j;
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(out_c.len());
                            let bits = plan.bit(g);
                            let dp =
                                dplans[width_slot(bits)].as_ref().expect("resolved above");
                            unpack_range(
                                &packed[offsets[g]..offsets[g + 1]],
                                bits,
                                0,
                                &mut scratch[..hi - lo],
                            );
                            dequantize_block(
                                dp,
                                zeros[g],
                                ranges[g],
                                &scratch[..hi - lo],
                                &mut out_c[lo..hi],
                            );
                        }
                    });
                }
            });
        }
        Matrix::from_vec(rows, cols, out)
    }
}

impl Default for QuantEngine {
    /// Defaults to [`Self::auto`].
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 4.0 - 2.0)
    }

    #[test]
    fn effective_shards_respects_gating() {
        let e = QuantEngine::from_config(&ParallelismConfig {
            threads: 8,
            min_blocks_per_shard: 100,
        });
        assert_eq!(e.effective_shards(50), 1); // too few blocks
        assert_eq!(e.effective_shards(199), 1); // < 2 full shards
        assert_eq!(e.effective_shards(200), 2);
        assert_eq!(e.effective_shards(450), 4);
        assert_eq!(e.effective_shards(10_000), 8); // capped by threads
        assert_eq!(QuantEngine::serial().effective_shards(10_000), 1);
    }

    #[test]
    fn auto_resolves_to_at_least_one_thread() {
        assert!(QuantEngine::auto().threads() >= 1);
        assert!(QuantEngine::with_threads(0).threads() >= 1);
        assert_eq!(QuantEngine::with_threads(3).threads(), 3);
    }

    #[test]
    fn parallel_quantize_matches_serial_across_widths() {
        let h = sample_matrix(96, 32, 1); // 3072 scalars
        for bits in [2u32, 4, 8] {
            for group in [7usize, 32, 100] {
                let a = QuantEngine::serial()
                    .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                    .unwrap();
                for threads in [2usize, 5, 8] {
                    let b = QuantEngine::with_threads(threads)
                        .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                        .unwrap();
                    assert_eq!(a.packed, b.packed, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.zeros, b.zeros, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.ranges, b.ranges, "bits={bits} G={group} t={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_dequantize_matches_serial() {
        let h = sample_matrix(64, 48, 2);
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 24, 2, &BinSpec::Uniform, 5)
            .unwrap();
        let a = QuantEngine::serial().dequantize(&ct).unwrap();
        for threads in [2usize, 8] {
            let b = QuantEngine::with_threads(threads).dequantize(&ct).unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "t={threads}");
        }
    }

    #[test]
    fn vm_bins_parallel_matches_serial() {
        let h = sample_matrix(40, 16, 3);
        let bins = BinSpec::int2_vm(1.2, 1.8).unwrap();
        let a = QuantEngine::serial()
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        let b = QuantEngine::with_threads(4)
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.zeros, b.zeros);
    }

    #[test]
    fn pooled_calls_are_bit_identical_and_reuse_buffers() {
        let h = sample_matrix(32, 32, 4);
        let engine = QuantEngine::serial();
        let seed = 0xabcdu64;
        let plain = engine
            .quantize_seeded(&h, 16, 2, &BinSpec::Uniform, seed)
            .unwrap();
        let mut pool = BufferPool::new();
        let pooled = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(plain.packed, pooled.packed);
        assert_eq!(plain.zeros, pooled.zeros);
        assert_eq!(plain.ranges, pooled.ranges);
        let d1 = engine.dequantize(&pooled).unwrap();
        let d2 = engine.dequantize_pooled(&pooled, &mut pool).unwrap();
        assert_eq!(d1.as_slice(), d2.as_slice());
        // Run again: the scratch buffers must now come from the pool.
        let before = pool.stats().hits;
        let again = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(again.packed, plain.packed);
        assert!(
            pool.stats().hits > before,
            "pool not reused: {:?}",
            pool.stats()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty = Matrix::zeros(0, 5);
        let ct = QuantEngine::with_threads(4)
            .quantize_seeded(&empty, 8, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.num_groups(), 0);
        assert_eq!(ct.dequantize().unwrap().shape(), (0, 5));

        let one = Matrix::from_vec(1, 1, vec![3.5]).unwrap();
        let ct = QuantEngine::with_threads(8)
            .quantize_seeded(&one, 4, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.dequantize().unwrap().as_slice(), &[3.5]);
    }

    #[test]
    fn planned_quantize_matches_serial_across_threads() {
        let h = sample_matrix(128, 32, 21); // 4096 scalars
        let mut rng = Pcg64::new(22);
        // A deliberately mixed plan: 128 blocks of 32 scalars.
        let bits: Vec<u8> = (0..128)
            .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
            .collect();
        let plan = BitPlan::new(bits, 32).unwrap();
        let reference = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 0xbeef)
            .unwrap();
        for threads in [2usize, 5, 8] {
            let pt = QuantEngine::with_threads(threads)
                .quantize_planned_seeded(&h, &plan, 0xbeef)
                .unwrap();
            assert_eq!(pt.packed, reference.packed, "t={threads}");
            assert_eq!(pt.zeros, reference.zeros, "t={threads}");
            assert_eq!(pt.ranges, reference.ranges, "t={threads}");
            let a = QuantEngine::serial().dequantize_planned(&reference).unwrap();
            let b = QuantEngine::with_threads(threads)
                .dequantize_planned(&pt)
                .unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "t={threads}");
        }
    }

    #[test]
    fn uniform_plan_matches_fixed_width_path_bit_exactly() {
        // A constant-width plan must reproduce the fixed-width engine
        // byte for byte: same per-block streams, same packing layout
        // (every full block is byte-aligned in both).
        let h = sample_matrix(64, 32, 23); // 2048 scalars, G=32 divides evenly
        for bits in [2u32, 4, 8] {
            let fixed = QuantEngine::serial()
                .quantize_seeded(&h, 32, bits, &BinSpec::Uniform, 77)
                .unwrap();
            let plan = BitPlan::uniform(bits, 64, 32).unwrap();
            let planned = QuantEngine::with_threads(4)
                .quantize_planned_seeded(&h, &plan, 77)
                .unwrap();
            assert_eq!(planned.packed, fixed.packed, "bits={bits}");
            assert_eq!(planned.zeros, fixed.zeros, "bits={bits}");
            assert_eq!(planned.ranges, fixed.ranges, "bits={bits}");
            let a = fixed.dequantize().unwrap();
            let b = planned.dequantize().unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "bits={bits}");
        }
    }

    #[test]
    fn planned_pooled_calls_are_bit_identical_and_reuse_buffers() {
        let h = sample_matrix(32, 32, 24);
        let plan = BitPlan::new(
            (0..64).map(|g| if g % 2 == 0 { 1u8 } else { 4 }).collect(),
            16,
        )
        .unwrap();
        let engine = QuantEngine::serial();
        let plain = engine.quantize_planned_seeded(&h, &plan, 5).unwrap();
        let mut pool = BufferPool::new();
        let pooled = engine
            .quantize_planned_impl(&h, &plan, 5, Some(&mut pool))
            .unwrap();
        assert_eq!(plain.packed, pooled.packed);
        assert_eq!(plain.zeros, pooled.zeros);
        let d1 = engine.dequantize_planned(&pooled).unwrap();
        let d2 = engine.dequantize_planned_pooled(&pooled, &mut pool).unwrap();
        assert_eq!(d1.as_slice(), d2.as_slice());
        // Recycle the consumed packed buffer like the pipeline's backward
        // pass does; the next step's packed take must then hit the pool.
        pool.put_bytes(pooled.packed.clone());
        let before = pool.stats().hits;
        let again = engine
            .quantize_planned_impl(&h, &plan, 5, Some(&mut pool))
            .unwrap();
        assert_eq!(again.packed, plain.packed);
        assert!(pool.stats().hits > before, "pool not reused");
    }

    #[test]
    fn planned_error_bounded_by_block_width() {
        // |ĥ - h| <= range_g / (2^{b_g} - 1) for each block's own width.
        let h = sample_matrix(16, 32, 25);
        let bits: Vec<u8> = (0..32).map(|g| [1u8, 2, 4, 8][g % 4]).collect();
        let plan = BitPlan::new(bits, 16).unwrap();
        let pt = QuantEngine::with_threads(3)
            .quantize_planned_seeded(&h, &plan, 9)
            .unwrap();
        let d = pt.dequantize().unwrap();
        for (idx, (&orig, &deq)) in h.as_slice().iter().zip(d.as_slice()).enumerate() {
            let g = idx / 16;
            let b = ((1u32 << plan.bit(g)) - 1) as f32;
            let width = pt.ranges[g] / b;
            assert!(
                (orig - deq).abs() <= width * 1.0001,
                "idx={idx} bits={}: |{orig} - {deq}| > {width}",
                plan.bit(g)
            );
        }
    }

    #[test]
    fn planned_handles_ragged_and_empty() {
        // 1221 scalars, G=100 -> 13 blocks, last has 21 scalars.
        let h = sample_matrix(33, 37, 26);
        let bits: Vec<u8> = (0..13).map(|g| [2u8, 8][g % 2]).collect();
        let plan = BitPlan::new(bits, 100).unwrap();
        let a = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 31)
            .unwrap();
        let b = QuantEngine::with_threads(8)
            .quantize_planned_seeded(&h, &plan, 31)
            .unwrap();
        assert_eq!(a.packed, b.packed);
        assert_eq!(
            a.dequantize().unwrap().as_slice(),
            b.dequantize().unwrap().as_slice()
        );

        let empty = Matrix::zeros(0, 7);
        let plan = BitPlan::new(vec![], 8).unwrap();
        let pt = QuantEngine::with_threads(4)
            .quantize_planned_seeded(&empty, &plan, 1)
            .unwrap();
        assert_eq!(pt.num_groups(), 0);
        assert_eq!(pt.dequantize().unwrap().shape(), (0, 7));
    }

    #[test]
    fn planned_rejects_mismatched_plan() {
        let h = sample_matrix(8, 8, 27);
        // 64 scalars at G=16 need 4 blocks; give 3.
        let plan = BitPlan::new(vec![2, 2, 2], 16).unwrap();
        assert!(QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 1)
            .is_err());
        // Malformed planned tensor: truncated packed buffer.
        let good_plan = BitPlan::new(vec![2, 2, 2, 2], 16).unwrap();
        let mut pt = QuantEngine::serial()
            .quantize_planned_seeded(&h, &good_plan, 1)
            .unwrap();
        pt.packed.truncate(3);
        assert!(QuantEngine::serial().dequantize_planned(&pt).is_err());
        let mut pt2 = QuantEngine::serial()
            .quantize_planned_seeded(&h, &good_plan, 1)
            .unwrap();
        pt2.zeros.pop();
        assert!(QuantEngine::serial().dequantize_planned(&pt2).is_err());
    }

    #[test]
    fn dequantize_rejects_malformed_tensors() {
        let h = sample_matrix(8, 8, 5);
        let good = QuantEngine::serial()
            .quantize_seeded(&h, 8, 2, &BinSpec::Uniform, 2)
            .unwrap();
        let mut short = good.clone();
        short.packed.truncate(1);
        assert!(QuantEngine::serial().dequantize(&short).is_err());
        let mut missing_meta = good.clone();
        missing_meta.zeros.pop();
        assert!(QuantEngine::serial().dequantize(&missing_meta).is_err());
        let mut bad_bits = good;
        bad_bits.bits = 3;
        assert!(QuantEngine::serial().dequantize(&bad_bits).is_err());
    }
}
