//! Multi-threaded quantization execution engine.
//!
//! The independent-blocks structure of Eq. 6 makes every quantization
//! group — one `(zero-point, range)` pair plus its slice of codes —
//! embarrassingly parallel, which is exactly what ActNN and GACT exploit
//! for throughput. [`QuantEngine`] shards the flat block list of
//! [`BlockwiseQuantizer`](crate::quant::BlockwiseQuantizer) (and the
//! per-row groups of [`RowQuantizer`](crate::quant::RowQuantizer)) into
//! contiguous per-thread shards driven by `std::thread::scope`.
//!
//! ## Determinism
//!
//! Block `g` always draws its stochastic-rounding randomness from the
//! deterministic stream [`Pcg64::with_stream`]`(seed, g)` — the stream
//! assignment depends only on the block *index*, never on which worker
//! processes it or how many workers exist. Parallel output is therefore
//! **bit-identical to serial** for the same seed, at every bit width and
//! any thread count:
//!
//! ```
//! use iexact::engine::QuantEngine;
//! use iexact::quant::BinSpec;
//! use iexact::rngs::Pcg64;
//! use iexact::tensor::Matrix;
//!
//! let mut rng = Pcg64::new(7);
//! let h = Matrix::from_fn(64, 32, |_, _| rng.next_f32());
//! let serial = QuantEngine::serial()
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! let parallel = QuantEngine::with_threads(4)
//!     .quantize_seeded(&h, 32, 2, &BinSpec::Uniform, 42)
//!     .unwrap();
//! assert_eq!(serial.packed, parallel.packed);
//! assert_eq!(serial.zeros, parallel.zeros);
//! ```
//!
//! ## Configuration
//!
//! Production code builds the engine from the `[parallelism]` config
//! section via [`QuantEngine::from_config`]; see
//! [`ParallelismConfig`](crate::config::ParallelismConfig) for the
//! thread-count and shard-granularity knobs and the auto heuristic.

use crate::config::ParallelismConfig;
use crate::memory::BufferPool;
use crate::quant::{
    dequantize_block, pack_codes_into, quantize_block, unpack_range, BinSpec, CompressedTensor,
    DequantPlan, QuantPlan,
};
use crate::rngs::Pcg64;
use crate::tensor::Matrix;
use crate::{Error, Result};

/// Auto mode caps the worker count here: grouped quantization saturates
/// memory bandwidth well before it saturates very wide machines, and the
/// per-call `thread::scope` spawn cost grows with the worker count.
pub const MAX_AUTO_THREADS: usize = 8;

/// Resolve a configured thread count (`0` = auto) to a concrete one.
fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_AUTO_THREADS)
    }
}

/// Sharded executor for grouped quantize/dequantize.
///
/// Cheap to construct and `Clone`; holds no threads — workers are scoped
/// per call, so the engine can be shared freely across the pipeline,
/// coordinator and benches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantEngine {
    threads: usize,
    min_blocks_per_shard: usize,
}

impl QuantEngine {
    /// Single-threaded engine — the reference every parallel result is
    /// bit-compared against.
    pub fn serial() -> Self {
        QuantEngine {
            threads: 1,
            min_blocks_per_shard: 1,
        }
    }

    /// Engine with an explicit worker count (`0` = auto-detect). Shard
    /// gating is disabled (`min_blocks_per_shard = 1`) so even small
    /// inputs fan out — the right default for tests and benches;
    /// production configs go through [`Self::from_config`].
    pub fn with_threads(threads: usize) -> Self {
        QuantEngine {
            threads: resolve_threads(threads),
            min_blocks_per_shard: 1,
        }
    }

    /// Engine for the default [`ParallelismConfig`]: auto thread count,
    /// production shard gating.
    pub fn auto() -> Self {
        Self::from_config(&ParallelismConfig::default())
    }

    /// Build from the `[parallelism]` config section, resolving auto mode
    /// against `std::thread::available_parallelism`.
    pub fn from_config(cfg: &ParallelismConfig) -> Self {
        QuantEngine {
            threads: resolve_threads(cfg.threads),
            min_blocks_per_shard: cfg.min_blocks_per_shard.max(1),
        }
    }

    /// Resolved worker-count ceiling for this engine.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Worker count actually used for `num_blocks` independent blocks:
    /// stays serial until at least two shards of `min_blocks_per_shard`
    /// blocks exist (fan-out below that loses more to spawn overhead than
    /// it gains), then grows linearly and caps at the configured thread
    /// count.
    pub fn effective_shards(&self, num_blocks: usize) -> usize {
        if self.threads <= 1 {
            return 1;
        }
        if num_blocks < self.min_blocks_per_shard.saturating_mul(2) {
            return 1;
        }
        self.threads.min(num_blocks / self.min_blocks_per_shard).max(1)
    }

    /// Grouped quantization (Eq. 2 + Eq. 6) with randomness drawn from
    /// `rng`: one `u64` draw keys the per-block streams, so the caller's
    /// generator advances identically regardless of thread count.
    pub fn quantize(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
    ) -> Result<CompressedTensor> {
        self.quantize_seeded(h, group_len, bits, bins, rng.next_u64())
    }

    /// Seed-addressed grouped quantization. Bit-identical across engines:
    /// `serial().quantize_seeded(..)` ==
    /// `with_threads(n).quantize_seeded(..)` for every `n`.
    pub fn quantize_seeded(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, seed, None)
    }

    /// [`Self::quantize`] with scratch and output buffers recycled
    /// through `pool` — the packed buffer comes from the pool and the
    /// code scratch returns to it, so steady-state training does no
    /// per-layer allocation for the compressed path.
    pub fn quantize_pooled(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        rng: &mut Pcg64,
        pool: &mut BufferPool,
    ) -> Result<CompressedTensor> {
        self.quantize_impl(h, group_len, bits, bins, rng.next_u64(), Some(pool))
    }

    fn quantize_impl(
        &self,
        h: &Matrix,
        group_len: usize,
        bits: u32,
        bins: &BinSpec,
        seed: u64,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<CompressedTensor> {
        let plan = QuantPlan::resolve(bits, bins, group_len)?;
        let data = h.as_slice();
        let n = data.len();
        let num_groups = n.div_ceil(group_len);

        // Scratch contents are unspecified: quantize_block writes every
        // element of each block (including the constant-block fill).
        let mut codes = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_scratch(n),
            None => vec![0u8; n],
        };
        let mut zeros = vec![0f32; num_groups];
        let mut ranges = vec![0f32; num_groups];

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                let mut rng_g = Pcg64::with_stream(seed, g as u64);
                let (z, r) =
                    quantize_block(&plan, &data[start..end], &mut codes[start..end], &mut rng_g);
                zeros[g] = z;
                ranges[g] = r;
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let plan = &plan;
            std::thread::scope(|s| {
                for (idx, (((data_c, codes_c), zeros_c), ranges_c)) in data
                    .chunks(chunk)
                    .zip(codes.chunks_mut(chunk))
                    .zip(zeros.chunks_mut(groups_per_shard))
                    .zip(ranges.chunks_mut(groups_per_shard))
                    .enumerate()
                {
                    let base = idx * groups_per_shard;
                    s.spawn(move || {
                        for (j, (z, r)) in
                            zeros_c.iter_mut().zip(ranges_c.iter_mut()).enumerate()
                        {
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(data_c.len());
                            let mut rng_g = Pcg64::with_stream(seed, (base + j) as u64);
                            let (zz, rr) = quantize_block(
                                plan,
                                &data_c[lo..hi],
                                &mut codes_c[lo..hi],
                                &mut rng_g,
                            );
                            *z = zz;
                            *r = rr;
                        }
                    });
                }
            });
        }

        let mut packed = match pool.as_deref_mut() {
            Some(p) => p.take_bytes_empty((n * bits as usize).div_ceil(8)),
            None => Vec::new(),
        };
        pack_codes_into(&codes, bits, &mut packed)?;
        if let Some(p) = pool.as_deref_mut() {
            p.put_bytes(codes);
        }
        Ok(CompressedTensor {
            packed,
            zeros,
            ranges,
            shape: h.shape(),
            group_len,
            bits,
            bins: bins.clone(),
        })
    }

    /// Dequantize (Eq. 3), sharding the group loop across worker threads.
    /// Purely deterministic, so parallel and serial results are
    /// bit-identical by construction.
    pub fn dequantize(&self, ct: &CompressedTensor) -> Result<Matrix> {
        self.dequantize_impl(ct, None)
    }

    /// [`Self::dequantize`] with the output and code-scratch buffers
    /// drawn from (and returned to) `pool`.
    pub fn dequantize_pooled(
        &self,
        ct: &CompressedTensor,
        pool: &mut BufferPool,
    ) -> Result<Matrix> {
        self.dequantize_impl(ct, Some(pool))
    }

    fn dequantize_impl(
        &self,
        ct: &CompressedTensor,
        mut pool: Option<&mut BufferPool>,
    ) -> Result<Matrix> {
        if !matches!(ct.bits, 2 | 4 | 8) {
            return Err(Error::Config(format!("unsupported bit width {}", ct.bits)));
        }
        if ct.group_len == 0 {
            return Err(Error::Config("group_len must be positive".into()));
        }
        let (rows, cols) = ct.shape;
        let n = rows * cols;
        let num_groups = n.div_ceil(ct.group_len);
        let codes_per_byte = (8 / ct.bits) as usize;
        if ct.packed.len() * codes_per_byte < n {
            return Err(Error::Shape(format!(
                "packed buffer too short: wanted {n} codes, got {}",
                ct.packed.len() * codes_per_byte
            )));
        }
        if ct.zeros.len() != num_groups || ct.ranges.len() != num_groups {
            return Err(Error::Shape(format!(
                "expected {num_groups} (zero, range) pairs, got ({}, {})",
                ct.zeros.len(),
                ct.ranges.len()
            )));
        }
        let plan = DequantPlan::resolve(ct.bits, &ct.bins);
        let group_len = ct.group_len;
        // Every element of `out` (and the unpack scratch) is overwritten
        // group by group, so unspecified-content takes are safe.
        let mut out = match pool.as_deref_mut() {
            Some(p) => p.take_floats_scratch(n),
            None => vec![0f32; n],
        };

        let shards = self.effective_shards(num_groups);
        if shards <= 1 {
            let mut scratch = match pool.as_deref_mut() {
                Some(p) => p.take_bytes_scratch(n),
                None => vec![0u8; n],
            };
            unpack_range(&ct.packed, ct.bits, 0, &mut scratch);
            for g in 0..num_groups {
                let start = g * group_len;
                let end = (start + group_len).min(n);
                dequantize_block(
                    &plan,
                    ct.zeros[g],
                    ct.ranges[g],
                    &scratch[start..end],
                    &mut out[start..end],
                );
            }
            if let Some(p) = pool.as_deref_mut() {
                p.put_bytes(scratch);
            }
        } else {
            let groups_per_shard = num_groups.div_ceil(shards);
            let chunk = groups_per_shard * group_len;
            let shard_count = num_groups.div_ceil(groups_per_shard);
            // Per-shard unpack scratch, drawn from the pool up front so
            // the steady-state parallel path stays allocation-free too.
            let mut scratches: Vec<Vec<u8>> = (0..shard_count)
                .map(|i| {
                    let len = chunk.min(n - i * chunk);
                    match pool.as_deref_mut() {
                        Some(p) => p.take_bytes_scratch(len),
                        None => vec![0u8; len],
                    }
                })
                .collect();
            let plan = &plan;
            let packed = ct.packed.as_slice();
            let zeros = ct.zeros.as_slice();
            let ranges = ct.ranges.as_slice();
            let bits = ct.bits;
            std::thread::scope(|s| {
                for (idx, (((out_c, zeros_c), ranges_c), scratch)) in out
                    .chunks_mut(chunk)
                    .zip(zeros.chunks(groups_per_shard))
                    .zip(ranges.chunks(groups_per_shard))
                    .zip(scratches.iter_mut())
                    .enumerate()
                {
                    s.spawn(move || {
                        // Each shard unpacks only its own scalar range —
                        // in-bounds by the packed-length check above.
                        unpack_range(packed, bits, idx * chunk, scratch);
                        for (j, (&z, &r)) in zeros_c.iter().zip(ranges_c).enumerate() {
                            let lo = j * group_len;
                            let hi = (lo + group_len).min(out_c.len());
                            dequantize_block(
                                plan,
                                z,
                                r,
                                &scratch[lo..hi],
                                &mut out_c[lo..hi],
                            );
                        }
                    });
                }
            });
            if let Some(p) = pool.as_deref_mut() {
                for scratch in scratches {
                    p.put_bytes(scratch);
                }
            }
        }
        Matrix::from_vec(rows, cols, out)
    }
}

impl Default for QuantEngine {
    /// Defaults to [`Self::auto`].
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 4.0 - 2.0)
    }

    #[test]
    fn effective_shards_respects_gating() {
        let e = QuantEngine::from_config(&ParallelismConfig {
            threads: 8,
            min_blocks_per_shard: 100,
        });
        assert_eq!(e.effective_shards(50), 1); // too few blocks
        assert_eq!(e.effective_shards(199), 1); // < 2 full shards
        assert_eq!(e.effective_shards(200), 2);
        assert_eq!(e.effective_shards(450), 4);
        assert_eq!(e.effective_shards(10_000), 8); // capped by threads
        assert_eq!(QuantEngine::serial().effective_shards(10_000), 1);
    }

    #[test]
    fn auto_resolves_to_at_least_one_thread() {
        assert!(QuantEngine::auto().threads() >= 1);
        assert!(QuantEngine::with_threads(0).threads() >= 1);
        assert_eq!(QuantEngine::with_threads(3).threads(), 3);
    }

    #[test]
    fn parallel_quantize_matches_serial_across_widths() {
        let h = sample_matrix(96, 32, 1); // 3072 scalars
        for bits in [2u32, 4, 8] {
            for group in [7usize, 32, 100] {
                let a = QuantEngine::serial()
                    .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                    .unwrap();
                for threads in [2usize, 5, 8] {
                    let b = QuantEngine::with_threads(threads)
                        .quantize_seeded(&h, group, bits, &BinSpec::Uniform, 99)
                        .unwrap();
                    assert_eq!(a.packed, b.packed, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.zeros, b.zeros, "bits={bits} G={group} t={threads}");
                    assert_eq!(a.ranges, b.ranges, "bits={bits} G={group} t={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_dequantize_matches_serial() {
        let h = sample_matrix(64, 48, 2);
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 24, 2, &BinSpec::Uniform, 5)
            .unwrap();
        let a = QuantEngine::serial().dequantize(&ct).unwrap();
        for threads in [2usize, 8] {
            let b = QuantEngine::with_threads(threads).dequantize(&ct).unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "t={threads}");
        }
    }

    #[test]
    fn vm_bins_parallel_matches_serial() {
        let h = sample_matrix(40, 16, 3);
        let bins = BinSpec::int2_vm(1.2, 1.8).unwrap();
        let a = QuantEngine::serial()
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        let b = QuantEngine::with_threads(4)
            .quantize_seeded(&h, 16, 2, &bins, 13)
            .unwrap();
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.zeros, b.zeros);
    }

    #[test]
    fn pooled_calls_are_bit_identical_and_reuse_buffers() {
        let h = sample_matrix(32, 32, 4);
        let engine = QuantEngine::serial();
        let seed = 0xabcdu64;
        let plain = engine
            .quantize_seeded(&h, 16, 2, &BinSpec::Uniform, seed)
            .unwrap();
        let mut pool = BufferPool::new();
        let pooled = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(plain.packed, pooled.packed);
        assert_eq!(plain.zeros, pooled.zeros);
        assert_eq!(plain.ranges, pooled.ranges);
        let d1 = engine.dequantize(&pooled).unwrap();
        let d2 = engine.dequantize_pooled(&pooled, &mut pool).unwrap();
        assert_eq!(d1.as_slice(), d2.as_slice());
        // Run again: the scratch buffers must now come from the pool.
        let before = pool.stats().hits;
        let again = engine
            .quantize_impl(&h, 16, 2, &BinSpec::Uniform, seed, Some(&mut pool))
            .unwrap();
        assert_eq!(again.packed, plain.packed);
        assert!(
            pool.stats().hits > before,
            "pool not reused: {:?}",
            pool.stats()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty = Matrix::zeros(0, 5);
        let ct = QuantEngine::with_threads(4)
            .quantize_seeded(&empty, 8, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.num_groups(), 0);
        assert_eq!(ct.dequantize().unwrap().shape(), (0, 5));

        let one = Matrix::from_vec(1, 1, vec![3.5]).unwrap();
        let ct = QuantEngine::with_threads(8)
            .quantize_seeded(&one, 4, 2, &BinSpec::Uniform, 1)
            .unwrap();
        assert_eq!(ct.dequantize().unwrap().as_slice(), &[3.5]);
    }

    #[test]
    fn dequantize_rejects_malformed_tensors() {
        let h = sample_matrix(8, 8, 5);
        let good = QuantEngine::serial()
            .quantize_seeded(&h, 8, 2, &BinSpec::Uniform, 2)
            .unwrap();
        let mut short = good.clone();
        short.packed.truncate(1);
        assert!(QuantEngine::serial().dequantize(&short).is_err());
        let mut missing_meta = good.clone();
        missing_meta.zeros.pop();
        assert!(QuantEngine::serial().dequantize(&missing_meta).is_err());
        let mut bad_bits = good;
        bad_bits.bits = 3;
        assert!(QuantEngine::serial().dequantize(&bad_bits).is_err());
    }
}
