//! Wire framing for the distributed coordinator.
//!
//! Every message between the leader and a worker travels in one frame:
//!
//! ```text
//! magic    8 B  b"IEXADIST"
//! version  4 B  u32 LE (PROTO_VERSION)
//! endian   4 B  u32 LE (ENDIAN_TAG — reads back scrambled on a
//!               big-endian peer, like PartitionStore's manifest guard)
//! len      8 B  u64 LE payload length
//! payload  len  message bytes (see `proto`)
//! checksum 8 B  u64 LE FNV-1a over everything above
//! ```
//!
//! The functions are generic over `io::Read`/`io::Write` so the
//! corruption tests drive them through in-memory cursors, and every
//! malformed-frame path returns a *named* protocol error
//! (`runtime error: dist protocol: ...`) rather than a bare I/O error —
//! a garbage peer and a dead peer are different diagnoses.

use crate::checkpoint::fnv1a;
use crate::{Error, Result};
use std::io::{Read, Write};

pub(crate) const FRAME_MAGIC: &[u8; 8] = b"IEXADIST";
pub(crate) const PROTO_VERSION: u32 = 1;
pub(crate) const ENDIAN_TAG: u32 = 0x0102_0304;

/// Frames above this are certainly a protocol desync, not a real
/// message — reject before allocating.
const MAX_PAYLOAD: u64 = 1 << 32;

fn proto_err(msg: impl std::fmt::Display) -> Error {
    Error::Runtime(format!("dist protocol: {msg}"))
}

/// Write one frame around `payload`.
pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(32 + payload.len());
    buf.extend_from_slice(FRAME_MAGIC);
    buf.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    buf.extend_from_slice(&ENDIAN_TAG.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(payload);
    let checksum = fnv1a(&buf);
    buf.extend_from_slice(&checksum.to_le_bytes());
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, validating magic, version, endianness tag, length
/// bound and checksum; returns the payload. Short reads surface as the
/// underlying `io error` (a closed socket is how a dead worker is
/// detected), every other mismatch as a named `dist protocol` error.
pub(crate) fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; 24];
    r.read_exact(&mut head)?;
    if &head[..8] != FRAME_MAGIC {
        return Err(proto_err("bad frame magic (not an iexact dist peer?)"));
    }
    let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
    if version != PROTO_VERSION {
        return Err(proto_err(format!(
            "protocol version {version}, expected {PROTO_VERSION}"
        )));
    }
    let endian = u32::from_le_bytes(head[12..16].try_into().unwrap());
    if endian != ENDIAN_TAG {
        return Err(proto_err(format!(
            "endianness tag {endian:#010x}, expected {ENDIAN_TAG:#010x} \
             (mixed-endian hosts are not supported)"
        )));
    }
    let len = u64::from_le_bytes(head[16..24].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(proto_err(format!("frame length {len} exceeds {MAX_PAYLOAD}")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut tail = [0u8; 8];
    r.read_exact(&mut tail)?;
    let stored = u64::from_le_bytes(tail);
    let mut sum = fnv1a(&head);
    for &b in &payload {
        sum ^= b as u64;
        sum = sum.wrapping_mul(0x100_0000_01b3);
    }
    if sum != stored {
        return Err(proto_err("frame checksum mismatch"));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn frame_round_trips() {
        for payload in [&b""[..], &b"x"[..], &[0u8; 4096][..]] {
            let buf = roundtrip(payload);
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let buf = roundtrip(b"hello");
        for cut in [0, 10, buf.len() - 1] {
            let err = read_frame(&mut Cursor::new(&buf[..cut])).unwrap_err();
            assert!(
                matches!(err, crate::Error::Io(_)),
                "cut at {cut}: expected io error, got {err}"
            );
        }
    }

    #[test]
    fn garbage_frames_are_named_protocol_errors() {
        // Wrong magic.
        let mut buf = roundtrip(b"payload");
        buf[0] ^= 0xff;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("dist protocol"), "{msg}");
        assert!(msg.contains("magic"), "{msg}");
        // Wrong version.
        let mut buf = roundtrip(b"payload");
        buf[8] = 99;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("protocol version 99"), "{msg}");
        // Wrong endianness tag.
        let mut buf = roundtrip(b"payload");
        buf[12..16].copy_from_slice(&0x0403_0201u32.to_le_bytes());
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("endianness"), "{msg}");
        // Corrupted payload byte: checksum must catch it.
        let mut buf = roundtrip(b"payload");
        buf[26] ^= 0x40;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("checksum"), "{msg}");
        // Absurd length field.
        let mut buf = roundtrip(b"payload");
        buf[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("frame length"), "{msg}");
    }
}
