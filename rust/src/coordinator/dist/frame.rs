//! Wire framing for the distributed coordinator.
//!
//! Every message between the leader and a worker travels in one frame:
//!
//! ```text
//! magic    8 B  b"IEXADIST"
//! version  4 B  u32 LE (PROTO_VERSION)
//! endian   4 B  u32 LE (ENDIAN_TAG — reads back scrambled on a
//!               big-endian peer, like PartitionStore's manifest guard)
//! len      8 B  u64 LE payload length
//! payload  len  message bytes (see `proto`)
//! checksum 8 B  u64 LE FNV-1a over everything above
//! ```
//!
//! The free functions are generic over `io::Read`/`io::Write` so the
//! corruption tests drive them through in-memory cursors, and every
//! malformed-frame path returns a *named* protocol error
//! (`runtime error: dist protocol: ...`) rather than a bare I/O error —
//! a garbage peer and a dead peer are different diagnoses. A third
//! diagnosis joined in PR 10: an expired socket deadline surfaces as
//! [`Error::Timeout`], distinct from dead-peer `Io`, because a *suspect*
//! peer may still recover.
//!
//! [`FrameConn`] wraps a `TcpStream` with per-operation deadlines and a
//! **resumable** frame reader: a deadline that expires mid-frame leaves
//! the partially-read bytes buffered, so a retried read continues the
//! same frame instead of desyncing the stream (a plain `read_exact`
//! would silently discard the prefix it already consumed). It is also
//! the attachment point for the deterministic chaos layer
//! ([`super::chaos`]), which perturbs outgoing frames by message index.

use super::chaos::{ChaosState, Fault};
use crate::checkpoint::fnv1a;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

pub(crate) const FRAME_MAGIC: &[u8; 8] = b"IEXADIST";
pub(crate) const PROTO_VERSION: u32 = 2;
pub(crate) const ENDIAN_TAG: u32 = 0x0102_0304;

const HEADER_LEN: usize = 24;
const TAIL_LEN: usize = 8;

/// Frames above this are certainly a protocol desync, not a real
/// message — reject before allocating.
const MAX_PAYLOAD: u64 = 1 << 32;

fn proto_err(msg: impl std::fmt::Display) -> Error {
    Error::Runtime(format!("dist protocol: {msg}"))
}

/// Map an I/O failure to the right diagnosis: an expired socket
/// deadline (`WouldBlock`/`TimedOut`, platform-dependent) becomes a
/// named [`Error::Timeout`] — the peer is *suspect*, not dead — and
/// everything else stays a dead-peer [`Error::Io`].
fn classify_io(e: std::io::Error, what: &str) -> Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            Error::Timeout(format!("{what} deadline expired"))
        }
        _ => Error::Io(e),
    }
}

/// Serialize one frame around `payload` (header + payload + checksum).
fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::with_capacity(HEADER_LEN + payload.len() + TAIL_LEN);
    buf.extend_from_slice(FRAME_MAGIC);
    buf.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    buf.extend_from_slice(&ENDIAN_TAG.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(payload);
    let checksum = fnv1a(&buf);
    buf.extend_from_slice(&checksum.to_le_bytes());
    buf
}

/// Validate a frame header, returning the payload length.
fn parse_header(head: &[u8; HEADER_LEN]) -> Result<usize> {
    if &head[..8] != FRAME_MAGIC {
        return Err(proto_err("bad frame magic (not an iexact dist peer?)"));
    }
    let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
    if version != PROTO_VERSION {
        return Err(proto_err(format!(
            "protocol version {version}, expected {PROTO_VERSION}"
        )));
    }
    let endian = u32::from_le_bytes(head[12..16].try_into().unwrap());
    if endian != ENDIAN_TAG {
        return Err(proto_err(format!(
            "endianness tag {endian:#010x}, expected {ENDIAN_TAG:#010x} \
             (mixed-endian hosts are not supported)"
        )));
    }
    let len = u64::from_le_bytes(head[16..24].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(proto_err(format!("frame length {len} exceeds {MAX_PAYLOAD}")));
    }
    Ok(len as usize)
}

/// Verify the trailing FNV-1a checksum of `head + payload`.
fn check_checksum(head: &[u8; HEADER_LEN], payload: &[u8], stored: u64) -> Result<()> {
    let mut sum = fnv1a(head);
    for &b in payload {
        sum ^= b as u64;
        sum = sum.wrapping_mul(0x100_0000_01b3);
    }
    if sum != stored {
        return Err(proto_err("frame checksum mismatch"));
    }
    Ok(())
}

/// Write one frame around `payload`.
pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    let buf = encode_frame(payload);
    w.write_all(&buf).map_err(|e| classify_io(e, "frame write"))?;
    w.flush().map_err(|e| classify_io(e, "frame flush"))?;
    Ok(())
}

/// Read one frame, validating magic, version, endianness tag, length
/// bound and checksum; returns the payload. Short reads surface as the
/// underlying `io error` (a closed socket is how a dead worker is
/// detected), an expired deadline as `Error::Timeout`, and every other
/// mismatch as a named `dist protocol` error.
///
/// NOT deadline-resumable: a timeout mid-frame leaves the stream
/// desynced. Peers with a retry budget must use [`FrameConn`].
pub(crate) fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head).map_err(|e| classify_io(e, "frame read"))?;
    let len = parse_header(&head)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| classify_io(e, "frame read"))?;
    let mut tail = [0u8; TAIL_LEN];
    r.read_exact(&mut tail).map_err(|e| classify_io(e, "frame read"))?;
    check_checksum(&head, &payload, u64::from_le_bytes(tail))?;
    Ok(payload)
}

/// A framed TCP connection with per-operation deadlines, a resumable
/// reader, and an optional chaos shim on outgoing frames.
///
/// Reads accumulate into an internal buffer capped at the current
/// frame's exact length (they never consume bytes of the next frame),
/// so an [`Error::Timeout`] from [`read_frame`](Self::read_frame) can
/// be retried and the read resumes where it stopped. Writes are *not*
/// retryable after a timeout — a partial frame already left the socket
/// — so callers must treat a write timeout as a dead peer.
pub(crate) struct FrameConn {
    stream: TcpStream,
    label: String,
    /// Partially-read bytes of the in-flight frame.
    rbuf: Vec<u8>,
    /// Total frame size (header + payload + tail) once the header has
    /// been parsed; `None` while still reading the header.
    want: Option<usize>,
    /// Outgoing message index (frames written), consumed by the chaos
    /// schedule.
    frames_written: u64,
    chaos: Option<ChaosState>,
}

impl FrameConn {
    /// Wrap `stream`; `label` names the peer in timeout messages.
    pub(crate) fn new(stream: TcpStream, label: impl Into<String>) -> Self {
        FrameConn {
            stream,
            label: label.into(),
            rbuf: Vec::new(),
            want: None,
            frames_written: 0,
            chaos: None,
        }
    }

    /// Set both socket deadlines; `0` blocks forever (the pre-PR-10
    /// behavior).
    pub(crate) fn set_deadline_ms(&mut self, ms: u64) -> Result<()> {
        let d = if ms == 0 { None } else { Some(Duration::from_millis(ms)) };
        self.stream.set_read_timeout(d)?;
        self.stream.set_write_timeout(d)?;
        Ok(())
    }

    /// Rename the peer once its identity is known (e.g. after `Hello`).
    pub(crate) fn set_label(&mut self, label: impl Into<String>) {
        self.label = label.into();
    }

    /// Attach a deterministic fault schedule to outgoing frames.
    pub(crate) fn set_chaos(&mut self, state: ChaosState) {
        self.chaos = Some(state);
    }

    pub(crate) fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// Whether a timed-out read left a partial frame buffered (the
    /// stream is mid-frame and only a *resumed* read keeps it synced).
    pub(crate) fn mid_frame(&self) -> bool {
        !self.rbuf.is_empty()
    }

    /// Write one frame, applying the chaos schedule if armed. A `Drop`
    /// or `Truncate` fault severs the connection and returns the
    /// [`chaos kill marker`](super::chaos::is_chaos_kill) — the injected
    /// crash the supervisor is being tested against.
    pub(crate) fn write_frame(&mut self, payload: &[u8]) -> Result<()> {
        let idx = self.frames_written;
        self.frames_written += 1;
        let mut buf = encode_frame(payload);
        if let Some(chaos) = &self.chaos {
            match chaos.fault_at(idx) {
                None => {}
                Some(Fault::Delay { ms }) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(Fault::Drop) => {
                    let _ = self.stream.shutdown(std::net::Shutdown::Both);
                    return Err(super::chaos::kill_error("drop", idx));
                }
                Some(Fault::Truncate) => {
                    let cut = buf.len() / 2;
                    let _ = self.stream.write_all(&buf[..cut]);
                    let _ = self.stream.flush();
                    let _ = self.stream.shutdown(std::net::Shutdown::Both);
                    return Err(super::chaos::kill_error("truncate", idx));
                }
                Some(Fault::BitFlip) => {
                    // Flip one payload bit; the peer's checksum test
                    // must turn this into a named protocol error.
                    let pos = HEADER_LEN + payload.len() / 2;
                    buf[pos.min(buf.len() - 1)] ^= 0x40;
                }
            }
        }
        self.stream
            .write_all(&buf)
            .map_err(|e| classify_io(e, &format!("{}: frame write", self.label)))?;
        self.stream
            .flush()
            .map_err(|e| classify_io(e, &format!("{}: frame flush", self.label)))?;
        Ok(())
    }

    /// Read one frame, resumably. On `Error::Timeout` the bytes read so
    /// far stay buffered and a retry continues the same frame; any
    /// other error is terminal for the connection.
    pub(crate) fn read_frame(&mut self) -> Result<Vec<u8>> {
        loop {
            let target = match self.want {
                None => HEADER_LEN,
                Some(total) => total,
            };
            if self.rbuf.len() >= target {
                if self.want.is_none() {
                    let head: [u8; HEADER_LEN] = self.rbuf[..HEADER_LEN].try_into().unwrap();
                    let len = parse_header(&head)?;
                    self.want = Some(HEADER_LEN + len + TAIL_LEN);
                    continue;
                }
                let frame = std::mem::take(&mut self.rbuf);
                self.want = None;
                let head: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
                let payload = &frame[HEADER_LEN..target - TAIL_LEN];
                let stored =
                    u64::from_le_bytes(frame[target - TAIL_LEN..target].try_into().unwrap());
                check_checksum(&head, payload, stored)?;
                return Ok(payload.to_vec());
            }
            // Cap the raw read at the bytes this frame still needs so
            // the buffer never swallows the start of the next frame.
            let need = target - self.rbuf.len();
            let mut tmp = [0u8; 64 * 1024];
            let cap = need.min(tmp.len());
            let n = self
                .stream
                .read(&mut tmp[..cap])
                .map_err(|e| classify_io(e, &format!("{}: frame read", self.label)))?;
            if n == 0 {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("{}: peer closed the connection", self.label),
                )));
            }
            self.rbuf.extend_from_slice(&tmp[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::net::TcpListener;

    fn roundtrip(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn frame_round_trips() {
        for payload in [&b""[..], &b"x"[..], &[0u8; 4096][..]] {
            let buf = roundtrip(payload);
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let buf = roundtrip(b"hello");
        for cut in [0, 10, buf.len() - 1] {
            let err = read_frame(&mut Cursor::new(&buf[..cut])).unwrap_err();
            assert!(
                matches!(err, crate::Error::Io(_)),
                "cut at {cut}: expected io error, got {err}"
            );
        }
    }

    #[test]
    fn garbage_frames_are_named_protocol_errors() {
        // Wrong magic.
        let mut buf = roundtrip(b"payload");
        buf[0] ^= 0xff;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("dist protocol"), "{msg}");
        assert!(msg.contains("magic"), "{msg}");
        // Wrong version.
        let mut buf = roundtrip(b"payload");
        buf[8] = 99;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("protocol version 99"), "{msg}");
        // Wrong endianness tag.
        let mut buf = roundtrip(b"payload");
        buf[12..16].copy_from_slice(&0x0403_0201u32.to_le_bytes());
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("endianness"), "{msg}");
        // Corrupted payload byte: checksum must catch it.
        let mut buf = roundtrip(b"payload");
        buf[26] ^= 0x40;
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("checksum"), "{msg}");
        // Absurd length field.
        let mut buf = roundtrip(b"payload");
        buf[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        let msg = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(msg.contains("frame length"), "{msg}");
    }

    /// Localhost socket pair for FrameConn tests.
    fn tcp_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn conn_round_trips_and_survives_mid_frame_timeout() {
        let (client, server) = tcp_pair();
        let mut conn = FrameConn::new(server, "test peer");
        conn.set_deadline_ms(50).unwrap();

        // Trickle half a frame: the deadline expires mid-frame, the
        // partial bytes stay buffered, and a retried read finishes the
        // SAME frame once the rest arrives. A plain read_exact would
        // have discarded the prefix and desynced the stream.
        let frame = roundtrip(b"resumable payload");
        let (half, rest) = frame.split_at(frame.len() / 2);
        let mut w = &client;
        w.write_all(half).unwrap();
        w.flush().unwrap();
        let err = conn.read_frame().unwrap_err();
        assert!(
            matches!(err, Error::Timeout(_)),
            "expected Timeout, got {err}"
        );
        assert!(err.to_string().contains("test peer"), "{err}");
        assert!(conn.mid_frame());
        w.write_all(rest).unwrap();
        w.flush().unwrap();
        assert_eq!(conn.read_frame().unwrap(), b"resumable payload");
        assert!(!conn.mid_frame());

        // Full frames round-trip through the conn writer too.
        let mut back = FrameConn::new(client, "other side");
        back.write_frame(b"reply").unwrap();
        assert_eq!(conn.read_frame().unwrap(), b"reply");
    }

    #[test]
    fn conn_clean_close_is_io_not_timeout() {
        let (client, server) = tcp_pair();
        let mut conn = FrameConn::new(server, "test peer");
        conn.set_deadline_ms(1000).unwrap();
        drop(client);
        let err = conn.read_frame().unwrap_err();
        assert!(matches!(err, Error::Io(_)), "expected Io, got {err}");
        assert!(!conn.mid_frame());
    }
}
