//! Multi-process partition-parallel training over localhost TCP.
//!
//! `iexact train --workers N` turns the partitioned trainer into a
//! **leader** process that spawns `N` worker processes and drives them
//! through a small framed protocol (`frame`/`proto` submodules):
//!
//! 1. **Handshake** — each worker connects, sends `Hello{rank}`, and
//!    receives the full training context (dataset *spec*, seeds, quant
//!    and allocation config). Workers regenerate the dataset and
//!    re-partition it locally — no subgraph bytes cross the wire — and
//!    the agreement is cross-checked via the
//!    [`HaloOwnership`](crate::partition::HaloOwnership) fingerprint.
//! 2. **Epochs** — the leader broadcasts the epoch-start weights and a
//!    partition assignment to every live worker; workers run the shared
//!    `partition_train_step` kernel and stream back per-partition
//!    losses/gradients, which the leader folds **in fixed partition
//!    order** with the same core-train-count weights as the
//!    single-process loop, then takes the one Adam step per epoch.
//! 3. **Eval** — on eval epochs workers forward their partitions at the
//!    post-update weights and reply with the logits **in packed-code
//!    form** (the quantized [`BitPlan`](crate::alloc::BitPlan) bytes
//!    plus plan header — never dense `f32`); the leader parks the
//!    bodies directly into its
//!    [`ActivationCache`](crate::memory::ActivationCache) and assembles
//!    full-graph metrics exactly as
//!    [`train_partitioned_span`](crate::pipeline::train_partitioned_span)
//!    does.
//!
//! Because partition steps are addressed by `(epoch, partition)` — RNG
//! streams included — every step is a pure function of the epoch-start
//! weights, so the run is **bit-identical to single-process
//! [`train_partitioned`](crate::pipeline::train_partitioned) at any
//! worker count**, and any step may be recomputed anywhere. That is
//! also the fault story: a worker that dies mid-epoch (detected as an
//! I/O error on its socket) simply has its unfinished partitions
//! re-dispatched to the survivors, and a run restarted after a leader
//! crash resumes from the last `[distributed] checkpoint_path`
//! checkpoint ([`TrainState`](crate::checkpoint::TrainState) V2) with
//! the identical trajectory. See `docs/distributed-training.md`.

// The frame layer is shared crate-wide: the serving subsystem
// (`crate::serve`) speaks the same framed wire format with its own
// message tags, so framing bugs are fixed in exactly one place.
pub(crate) mod frame;
mod proto;

use crate::alloc::BitPlan;
use crate::checkpoint::{state_to_bytes, TrainState};
use crate::config::{DatasetSpec, QuantConfig, TrainConfig};
use crate::engine::QuantEngine;
use crate::linalg::softmax_cross_entropy;
use crate::memory::{ActivationCache, BufferPool};
use crate::metrics::{masked_accuracy, TrainCurve};
use crate::partition::{partition_dataset, HaloOwnership, PartitionSet};
use crate::pipeline::{
    allocate_plans, init_partitioned_run, pack_partition_logits, partition_train_step,
    resolve_layer_bins, GcnModel, PartitionTrainResult, TrainResult,
};
use crate::rngs::Pcg64;
use crate::tensor::Matrix;
use crate::util::timer::LapTimer;
use crate::{Error, Result};
use proto::Msg;
use std::net::{TcpListener, TcpStream};

fn proto_err(msg: impl std::fmt::Display) -> Error {
    Error::Runtime(format!("dist protocol: {msg}"))
}

fn write_msg(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    frame::write_frame(stream, &msg.encode())
}

fn read_msg(stream: &mut TcpStream) -> Result<Msg> {
    Msg::decode(&frame::read_frame(stream)?)
}

/// Write a checkpoint via temp-file-then-rename so a leader killed
/// mid-write can never leave a torn file where the resume path expects
/// a valid [`TrainState`].
fn write_checkpoint_atomic(path: &str, state: &TrainState) -> Result<()> {
    let bytes = state_to_bytes(state);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Worker-side knobs. The default is a plain worker; tests inject
/// faults through it.
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Fault injection: after this many partition training steps the
    /// worker exits without replying, so the leader observes exactly
    /// what a crashed worker looks like — a closed socket mid-epoch.
    pub fail_after_steps: Option<usize>,
}

/// Halo/eval traffic accounting: what actually crossed process
/// boundaries (packed codes + plan headers) vs. what shipping dense
/// `f32` activations would have cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireStats {
    /// Bytes of packed eval bodies received by the leader.
    pub halo_payload_bytes: u64,
    /// Bytes the same activations would occupy as dense `f32`.
    pub halo_f32_bytes: u64,
}

/// What a distributed run hands back: the single-process-identical
/// metrics/state plus wire accounting and the fault-recovery tally.
#[derive(Debug, Clone)]
pub struct DistTrainOutcome {
    /// Same shape (and bit-identical content) as single-process
    /// [`train_partitioned`](crate::pipeline::train_partitioned).
    pub result: PartitionTrainResult,
    /// End-of-run state; byte-identical under
    /// [`state_to_bytes`](crate::checkpoint::state_to_bytes) to the
    /// single-process run's.
    pub state: TrainState,
    pub wire: WireStats,
    /// Partitions re-dispatched to a surviving worker after their
    /// original owner died (0 in a healthy run).
    pub reassigned_partitions: usize,
}

struct WorkerLink {
    rank: u32,
    stream: TcpStream,
    alive: bool,
}

/// Accept exactly `n` workers and index them by their announced rank.
fn accept_workers(listener: &TcpListener, n: usize) -> Result<Vec<WorkerLink>> {
    let mut links: Vec<Option<WorkerLink>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (mut stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        match read_msg(&mut stream)? {
            Msg::Hello { rank } => {
                let r = rank as usize;
                if r >= n {
                    return Err(proto_err(format!(
                        "worker rank {rank} out of range (expected 0..{n})"
                    )));
                }
                if links[r].is_some() {
                    return Err(proto_err(format!("duplicate worker rank {rank}")));
                }
                links[r] = Some(WorkerLink {
                    rank,
                    stream,
                    alive: true,
                });
            }
            other => {
                return Err(proto_err(format!("expected Hello, got {}", other.kind())));
            }
        }
    }
    Ok(links
        .into_iter()
        .map(|l| l.expect("every rank connected exactly once"))
        .collect())
}

/// Scatter one request per partition over the live workers and gather
/// one parsed response per partition, **re-dispatching the partitions
/// of any worker that dies** (send or receive I/O error) until every
/// partition has a result or no worker survives.
///
/// Correct because every request is a pure function of its partition
/// index and the epoch-start weights: recomputing a dead worker's
/// partition elsewhere yields bit-identical results. Named protocol
/// errors (garbage frames, aborts, mismatched replies) are fatal —
/// only *dead* peers are survivable, confused ones are not.
fn dispatch<T>(
    links: &mut [WorkerLink],
    k: usize,
    reassigned: &mut usize,
    make: impl Fn(Vec<u64>) -> Msg,
    mut parse: impl FnMut(Msg, usize) -> Result<T>,
) -> Result<Vec<T>> {
    let mut out: Vec<Option<T>> = (0..k).map(|_| None).collect();
    let mut first_round = true;
    loop {
        let pending: Vec<usize> = (0..k).filter(|&p| out[p].is_none()).collect();
        if pending.is_empty() {
            break;
        }
        let alive: Vec<usize> = links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .map(|(i, _)| i)
            .collect();
        if alive.is_empty() {
            return Err(proto_err(format!(
                "all {} workers are dead with {} partition results outstanding",
                links.len(),
                pending.len()
            )));
        }
        if !first_round {
            *reassigned += pending.len();
        }
        first_round = false;
        // Round-robin the pending partitions over the live workers —
        // with all workers alive this is the static p % N assignment.
        let mut rounds: Vec<Vec<u64>> = vec![Vec::new(); links.len()];
        for (i, &p) in pending.iter().enumerate() {
            rounds[alive[i % alive.len()]].push(p as u64);
        }
        // Write every request before reading any response: workers
        // proceed independently, so the leader never deadlocks waiting
        // on a worker that is itself waiting to be asked.
        for (w, parts) in rounds.iter().enumerate() {
            if parts.is_empty() {
                continue;
            }
            if write_msg(&mut links[w].stream, &make(parts.clone())).is_err() {
                links[w].alive = false;
            }
        }
        for (w, parts) in rounds.iter().enumerate() {
            if parts.is_empty() || !links[w].alive {
                continue;
            }
            for &p in parts {
                match read_msg(&mut links[w].stream) {
                    Ok(Msg::Abort { reason }) => {
                        return Err(proto_err(format!(
                            "worker {} aborted: {reason}",
                            links[w].rank
                        )));
                    }
                    Ok(msg) => {
                        out[p as usize] = Some(parse(msg, p as usize)?);
                    }
                    Err(Error::Io(_)) => {
                        // Dead worker: everything it still owed goes
                        // back into the pool for the next round.
                        links[w].alive = false;
                        break;
                    }
                    Err(other) => return Err(other),
                }
            }
        }
    }
    Ok(out
        .into_iter()
        .map(|o| o.expect("loop exits only with every partition resolved"))
        .collect())
}

/// Drive a distributed training run as the **leader**: accept
/// `cfg.distributed.workers` connections on `listener`, hand each
/// worker the training context, and run the partitioned trainer's
/// epoch loop with all partition steps computed remotely.
///
/// The run is **bit-identical** to single-process
/// [`train_partitioned`](crate::pipeline::train_partitioned) on the
/// same `(spec, dataset_seed, quant, cfg, seed)` at any worker count —
/// same loss curve, same final weights, byte-identical
/// [`state_to_bytes`](crate::checkpoint::state_to_bytes) image — and
/// survives worker deaths by re-dispatching their partitions (see
/// [`DistTrainOutcome::reassigned_partitions`]). With
/// `cfg.distributed.checkpoint_path` set, a [`TrainState`] is written
/// atomically every `checkpoint_every_epochs`; pass a loaded state as
/// `resume` to continue a killed run with the identical trajectory.
///
/// The caller owns process management: bind the listener, spawn the
/// worker processes (or threads, in tests) pointed at its address,
/// then call this.
pub fn train_distributed(
    listener: &TcpListener,
    spec: &DatasetSpec,
    dataset_seed: u64,
    quant: &QuantConfig,
    cfg: &TrainConfig,
    seed: u64,
    resume: Option<TrainState>,
) -> Result<DistTrainOutcome> {
    quant.validate()?;
    cfg.validate()?;
    let dcfg = &cfg.distributed;
    if !dcfg.enabled() {
        return Err(Error::Config(
            "train_distributed requires distributed.workers >= 1".into(),
        ));
    }
    let dataset = spec.generate(dataset_seed);
    dataset.validate()?;
    let pcfg = &cfg.partition;
    let k = pcfg.num_partitions;
    let parts = partition_dataset(&dataset, k, pcfg.halo_hops)?;
    let fingerprint = HaloOwnership::build(&parts)?.fingerprint();
    let core_train_counts: Vec<usize> = parts.parts.iter().map(|p| p.core_train_count()).collect();
    let total_train: usize = core_train_counts.iter().sum();
    if total_train == 0 {
        return Err(Error::Config("dataset has no training nodes".into()));
    }
    let halo_nodes = parts.total_halo_nodes();
    let edge_cut_fraction = parts.edge_cut_fraction();
    // Scatter metadata for eval assembly; the subgraphs themselves live
    // on the workers, so the leader drops the partition set entirely.
    let assembly: Vec<(Vec<usize>, Vec<bool>)> = parts
        .parts
        .iter()
        .map(|p| (p.node_map.clone(), p.core_mask.clone()))
        .collect();
    drop(parts);

    let (start_epoch, mut model, mut adam, rng) =
        init_partitioned_run(&dataset, quant, cfg, seed, resume)?;

    let mut links = accept_workers(listener, dcfg.workers)?;
    let setup = proto::WorkerSetup {
        spec: spec.clone(),
        dataset_seed,
        seed,
        quant: quant.clone(),
        arch: cfg.arch,
        hidden_dim: cfg.hidden_dim,
        num_layers: cfg.num_layers,
        num_partitions: k,
        halo_hops: pcfg.halo_hops,
        cache_bits: pcfg.cache_bits,
        allocation: cfg.allocation.clone(),
        ownership_fingerprint: fingerprint,
    };
    for link in &mut links {
        write_msg(&mut link.stream, &Msg::Setup(Box::new(setup.clone())))?;
    }
    for link in &mut links {
        match read_msg(&mut link.stream)? {
            Msg::Ready { fingerprint: fp } if fp == fingerprint => {}
            Msg::Ready { fingerprint: fp } => {
                return Err(proto_err(format!(
                    "worker {} partitioning fingerprint {fp:#018x} disagrees with \
                     leader {fingerprint:#018x}",
                    link.rank
                )));
            }
            Msg::Abort { reason } => {
                return Err(proto_err(format!(
                    "worker {} aborted during handshake: {reason}",
                    link.rank
                )));
            }
            other => {
                return Err(proto_err(format!(
                    "expected Ready from worker {}, got {}",
                    link.rank,
                    other.kind()
                )));
            }
        }
    }

    let engine = QuantEngine::from_config(&cfg.parallelism);
    let mut pool = BufferPool::new();
    let mut cache = ActivationCache::new(k, seed ^ 0x00ca_c4ed);

    let mut curve = TrainCurve::default();
    let mut timer = LapTimer::new();
    let mut best_val_loss = f64::INFINITY;
    let mut test_at_best = 0.0;
    let mut max_stash = 0usize;
    let mut peak_resident = 0usize;
    let mut final_train_loss = f64::NAN;
    let mut wire = WireStats::default();
    let mut reassigned = 0usize;
    let n = dataset.num_nodes();

    for epoch in start_epoch..cfg.epochs {
        let t0 = std::time::Instant::now();
        let steps = dispatch(
            &mut links,
            k,
            &mut reassigned,
            |parts| Msg::Steps {
                epoch: epoch as u64,
                parts,
                weights: model.weights.clone(),
            },
            |msg, p| match msg {
                Msg::StepResult {
                    part,
                    loss,
                    stash_bytes,
                    grads,
                } if part as usize == p => Ok((loss, stash_bytes as usize, grads)),
                other => Err(proto_err(format!(
                    "expected StepResult for partition {p}, got {}",
                    other.kind()
                ))),
            },
        )?;
        // Fold in fixed partition order p = 0..k — the dispatch order
        // and worker count cannot leak into the accumulated gradient.
        let mut grad_acc: Vec<Matrix> = model
            .shapes()
            .iter()
            .map(|&(r, c)| Matrix::zeros(r, c))
            .collect();
        let mut loss_acc = 0.0f64;
        for (p, (loss, stash, grads)) in steps.into_iter().enumerate() {
            if grads.len() != grad_acc.len() {
                return Err(proto_err(format!(
                    "partition {p} returned {} gradient matrices, expected {}",
                    grads.len(),
                    grad_acc.len()
                )));
            }
            let w = core_train_counts[p] as f64 / total_train as f64;
            loss_acc += loss * w;
            for (a, g) in grad_acc.iter_mut().zip(&grads) {
                a.axpy(w as f32, g)?;
            }
            max_stash = max_stash.max(stash);
        }
        adam.step(&mut model.weights, &grad_acc)?;
        final_train_loss = loss_acc;

        if epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs {
            let bodies = dispatch(
                &mut links,
                k,
                &mut reassigned,
                |parts| Msg::Evals {
                    epoch: epoch as u64,
                    parts,
                    weights: model.weights.clone(),
                },
                |msg, p| match msg {
                    Msg::EvalResult { part, body } if part as usize == p => Ok(body),
                    other => Err(proto_err(format!(
                        "expected EvalResult for partition {p}, got {}",
                        other.kind()
                    ))),
                },
            )?;
            // Packed logits park straight into the cache — the wire body
            // *is* the cache entry, quantized on the worker under the
            // same slot seed stream a local park would use.
            for (p, body) in bodies.into_iter().enumerate() {
                wire.halo_payload_bytes += body.len() as u64;
                let pt = engine.decode_from_wire(&body, &mut pool)?;
                wire.halo_f32_bytes += (pt.shape.0 * pt.shape.1 * 4) as u64;
                cache.park_packed(p, pt, &mut pool)?;
            }
            peak_resident = peak_resident.max(cache.resident_bytes());
            let mut full = Matrix::zeros(n, dataset.num_classes);
            for (p, (node_map, core_mask)) in assembly.iter().enumerate() {
                let deq = cache
                    .fetch(p, &engine, &mut pool)?
                    .expect("parked in the loop above");
                for (local, &parent) in node_map.iter().enumerate() {
                    if core_mask[local] {
                        full.row_mut(parent).copy_from_slice(deq.row(local));
                    }
                }
                pool.put_floats(deq.into_vec());
            }
            let (val_loss, _) = softmax_cross_entropy(&full, &dataset.labels, &dataset.val_mask)?;
            let val_acc = masked_accuracy(&full, &dataset.labels, &dataset.val_mask);
            curve.push(epoch, loss_acc, val_loss, val_acc);
            if val_loss < best_val_loss {
                best_val_loss = val_loss;
                test_at_best = masked_accuracy(&full, &dataset.labels, &dataset.test_mask);
            }
        }

        if let Some(path) = &dcfg.checkpoint_path {
            let done = epoch + 1;
            if done % dcfg.checkpoint_every_epochs == 0 || done == cfg.epochs {
                let st = TrainState {
                    epoch: done,
                    model: model.clone(),
                    adam: adam.clone(),
                    rng: rng.clone(),
                    plans: None,
                };
                write_checkpoint_atomic(path, &st)?;
            }
        }
        timer.record(t0.elapsed());
    }

    // Best-effort: a worker that already died is already accounted for.
    for link in &mut links {
        if link.alive {
            let _ = write_msg(&mut link.stream, &Msg::Shutdown);
        }
    }

    let state = TrainState {
        epoch: cfg.epochs,
        model: model.clone(),
        adam,
        rng,
        plans: None,
    };
    Ok(DistTrainOutcome {
        result: PartitionTrainResult {
            result: TrainResult {
                test_accuracy: test_at_best,
                best_val_loss,
                curve,
                epochs_per_sec: timer.rate_per_sec(),
                stash_bytes: max_stash,
                final_train_loss,
            },
            peak_resident_bytes: peak_resident,
            cache_bytes: cache.resident_bytes() + cache.spilled_bytes(),
            num_partitions: k,
            halo_nodes,
            edge_cut_fraction,
            model,
        },
        state,
        wire,
        reassigned_partitions: reassigned,
    })
}

/// Run one **worker**: connect to the leader at `addr`, announce
/// `rank`, rebuild the training context from the Setup message
/// (regenerating the dataset and re-partitioning locally), then serve
/// step/eval requests until Shutdown.
///
/// All compute goes through the same `partition_train_step` /
/// `pack_partition_logits` kernels as the single-process trainer, on a
/// serial [`QuantEngine`] — results are bit-identical at any thread
/// count anyway, and worker processes already are the parallelism.
/// Eval replies carry the partition's logits as packed codes, never
/// dense `f32`.
pub fn run_worker(addr: &str, rank: u32, opts: &WorkerOptions) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    write_msg(&mut stream, &Msg::Hello { rank })?;
    let setup = match read_msg(&mut stream)? {
        Msg::Setup(s) => *s,
        Msg::Abort { reason } => {
            return Err(proto_err(format!("leader aborted: {reason}")));
        }
        other => {
            return Err(proto_err(format!("expected Setup, got {}", other.kind())));
        }
    };
    let dataset = setup.spec.generate(setup.dataset_seed);
    dataset.validate()?;
    let k = setup.num_partitions;
    let parts = partition_dataset(&dataset, k, setup.halo_hops)?;
    let fingerprint = HaloOwnership::build(&parts)?.fingerprint();
    if fingerprint != setup.ownership_fingerprint {
        // Training on a divergent partitioning would silently corrupt
        // the run; tell the leader why before bailing.
        let reason = format!(
            "worker {rank} partitioning fingerprint {fingerprint:#018x} disagrees \
             with leader {:#018x}",
            setup.ownership_fingerprint
        );
        let _ = write_msg(
            &mut stream,
            &Msg::Abort {
                reason: reason.clone(),
            },
        );
        return Err(proto_err(reason));
    }
    write_msg(&mut stream, &Msg::Ready { fingerprint })?;

    let bins = resolve_layer_bins(
        setup.arch,
        dataset.num_features(),
        setup.hidden_dim,
        dataset.num_classes,
        setup.num_layers,
        &setup.quant,
    )?;
    let allocator = setup.allocation.allocator(&setup.quant)?;
    let engine = QuantEngine::serial();
    let mut pool = BufferPool::new();
    let mut plans: Vec<Option<Vec<BitPlan>>> = vec![None; k];
    let mut plans_epoch: Option<u64> = None;
    let mut steps_done = 0usize;

    loop {
        match read_msg(&mut stream)? {
            Msg::Steps {
                epoch,
                parts: assigned,
                weights,
            } => {
                let model = GcnModel {
                    arch: setup.arch,
                    weights,
                };
                if let Some(alloc) = &allocator {
                    let e = epoch as usize;
                    if e % setup.allocation.realloc_interval_epochs == 0
                        && plans_epoch != Some(epoch)
                    {
                        // Re-solve *all* k partitions' plans, not just
                        // this round's: a mid-epoch reassignment may hand
                        // this worker any partition, and the stats
                        // streams are (epoch, partition)-addressed so the
                        // solve is identical wherever it runs.
                        for (p, slot) in plans.iter_mut().enumerate() {
                            let mut stats_rng =
                                Pcg64::with_stream(setup.seed ^ 0xb17a_1710, (e * k + p) as u64);
                            *slot = Some(allocate_plans(
                                &model,
                                &parts.parts[p].data,
                                &setup.quant,
                                alloc,
                                &mut stats_rng,
                            )?);
                        }
                        plans_epoch = Some(epoch);
                    }
                }
                for &pu in &assigned {
                    let p = checked_part(pu, &parts)?;
                    if let Some(limit) = opts.fail_after_steps {
                        if steps_done >= limit {
                            // Fault injection: vanish without replying —
                            // the leader sees the closed socket, exactly
                            // like a crashed worker process.
                            return Ok(());
                        }
                    }
                    let (loss, grads, stash) = partition_train_step(
                        &model,
                        &parts.parts[p].data,
                        &setup.quant,
                        &bins,
                        plans[p].as_deref(),
                        setup.seed,
                        epoch as usize,
                        k,
                        p,
                        &engine,
                        &mut pool,
                    )?;
                    steps_done += 1;
                    write_msg(
                        &mut stream,
                        &Msg::StepResult {
                            part: pu,
                            loss,
                            stash_bytes: stash as u64,
                            grads,
                        },
                    )?;
                }
            }
            Msg::Evals {
                epoch: _,
                parts: assigned,
                weights,
            } => {
                let model = GcnModel {
                    arch: setup.arch,
                    weights,
                };
                for &pu in &assigned {
                    let p = checked_part(pu, &parts)?;
                    let pt = pack_partition_logits(
                        &model,
                        &parts.parts[p].data,
                        setup.cache_bits,
                        setup.seed,
                        p,
                        &engine,
                        &mut pool,
                    )?;
                    let mut body = Vec::with_capacity(64 + pt.packed.len());
                    crate::memory::write_planned(&mut body, &pt);
                    pool.put_bytes(pt.packed);
                    write_msg(&mut stream, &Msg::EvalResult { part: pu, body })?;
                }
            }
            Msg::Shutdown => return Ok(()),
            Msg::Abort { reason } => {
                return Err(proto_err(format!("leader aborted: {reason}")));
            }
            other => {
                return Err(proto_err(format!(
                    "unexpected {} message on a serving worker",
                    other.kind()
                )));
            }
        }
    }
}

fn checked_part(pu: u64, parts: &PartitionSet) -> Result<usize> {
    let p = pu as usize;
    if p >= parts.parts.len() {
        return Err(proto_err(format!(
            "leader assigned partition {p}, but only {} exist",
            parts.parts.len()
        )));
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Arch;
    use crate::linalg::Adam;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("iexact_dist_{name}_{}", std::process::id()))
    }

    #[test]
    fn checkpoint_write_is_atomic_and_loadable() {
        let mut rng = Pcg64::new(7);
        let model = GcnModel::init_arch(Arch::Gcn, 4, 8, 3, 2, &mut rng).unwrap();
        let adam = Adam::new(1e-2, 0.0, &model.shapes());
        let state = TrainState {
            epoch: 5,
            model,
            adam,
            rng,
            plans: None,
        };
        let path = tmp_path("atomic_ckpt");
        let path_str = path.to_str().unwrap().to_string();
        write_checkpoint_atomic(&path_str, &state).unwrap();
        // The temp file must not linger and the artifact must round-trip.
        assert!(!std::path::Path::new(&format!("{path_str}.tmp")).exists());
        let loaded = crate::checkpoint::load_state(&path).unwrap();
        assert_eq!(loaded.epoch, 5);
        assert_eq!(
            crate::checkpoint::state_to_bytes(&loaded),
            crate::checkpoint::state_to_bytes(&state)
        );
        std::fs::remove_file(&path).unwrap();
    }
}
