//! Multi-process partition-parallel training over localhost TCP.
//!
//! `iexact train --workers N` turns the partitioned trainer into a
//! **leader** process that spawns `N` worker processes and drives them
//! through a small framed protocol (`frame`/`proto` submodules):
//!
//! 1. **Handshake** — each worker connects, sends `Hello{rank}`, and
//!    receives the full training context (dataset *spec*, seeds, quant
//!    and allocation config). Workers regenerate the dataset and
//!    re-partition it locally — no subgraph bytes cross the wire — and
//!    the agreement is cross-checked via the
//!    [`HaloOwnership`](crate::partition::HaloOwnership) fingerprint.
//! 2. **Epochs** — the leader broadcasts the epoch-start weights and a
//!    partition assignment to every live worker; workers run the shared
//!    `partition_train_step` kernel and stream back per-partition
//!    losses/gradients, which the leader folds **in fixed partition
//!    order** with the same core-train-count weights as the
//!    single-process loop, then takes the one Adam step per epoch.
//! 3. **Eval** — on eval epochs workers forward their partitions at the
//!    post-update weights and reply with the logits **in packed-code
//!    form** (the quantized [`BitPlan`](crate::alloc::BitPlan) bytes
//!    plus plan header — never dense `f32`); the leader parks the
//!    bodies directly into its
//!    [`ActivationCache`](crate::memory::ActivationCache) and assembles
//!    full-graph metrics exactly as
//!    [`train_partitioned_span`](crate::pipeline::train_partitioned_span)
//!    does.
//!
//! Because partition steps are addressed by `(epoch, partition)` — RNG
//! streams included — every step is a pure function of the epoch-start
//! weights, so the run is **bit-identical to single-process
//! [`train_partitioned`](crate::pipeline::train_partitioned) at any
//! worker count**, and any step may be recomputed anywhere.
//!
//! # Fault tolerance (PR 10)
//!
//! The leader runs a **supervisor** over its worker links: every socket
//! operation carries a `[fault_tolerance] io_timeout_ms` deadline
//! (surfaced as the named [`Error::Timeout`], distinct from dead-peer
//! `Io`), an expired read marks the worker *suspect* and retries with
//! capped exponential backoff (the frame layer resumes the partial
//! read), and exhausted retries declare it **dead** — its unfinished
//! partitions are re-dispatched to the survivors exactly as a closed
//! socket always was. Heartbeat probes at epoch boundaries catch hung
//! workers even between dispatches. A dead worker may be **restarted**
//! (bounded by `max_restarts`, via [`DistHooks::respawn`]): the
//! replacement announces `Rejoin{rank}` and receives a fresh `Setup`
//! whose `plans_from` carries the last realloc epoch's weights, so it
//! re-solves bit plans bit-identically to the survivors and the run's
//! result stays **bit-identical to an uninterrupted run**. The
//! [`chaos`] submodule injects deterministic faults (drop / delay /
//! truncate / bit-flip, addressed by `(rank, message-index)`) under
//! which `tests/chaos_dist.rs` proves exactly that property. A leader
//! killed mid-run still resumes from the `[distributed]
//! checkpoint_path` checkpoint ([`TrainState`]) with the identical
//! trajectory. See `docs/distributed-training.md`.

// The frame layer is shared crate-wide: the serving subsystem
// (`crate::serve`) speaks the same framed wire format with its own
// message tags, so framing bugs are fixed in exactly one place.
pub mod chaos;
pub(crate) mod frame;
mod proto;

use crate::alloc::BitPlan;
use crate::checkpoint::{state_to_bytes, TrainState};
use crate::config::{DatasetSpec, FaultToleranceConfig, QuantConfig, TrainConfig};
use crate::engine::QuantEngine;
use crate::linalg::softmax_cross_entropy;
use crate::memory::{ActivationCache, BufferPool};
use crate::metrics::{masked_accuracy, TrainCurve};
use crate::partition::{partition_dataset, HaloOwnership, PartitionSet};
use crate::pipeline::{
    allocate_plans, init_partitioned_run, pack_partition_logits, partition_train_step,
    resolve_layer_bins, GcnModel, PartitionTrainResult, TrainResult,
};
use crate::rngs::Pcg64;
use crate::tensor::Matrix;
use crate::util::timer::LapTimer;
use crate::{Error, Result};
use frame::FrameConn;
use proto::Msg;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

fn proto_err(msg: impl std::fmt::Display) -> Error {
    Error::Runtime(format!("dist protocol: {msg}"))
}

fn send(conn: &mut FrameConn, msg: &Msg) -> Result<()> {
    conn.write_frame(&msg.encode())
}

fn recv(conn: &mut FrameConn) -> Result<Msg> {
    Msg::decode(&conn.read_frame()?)
}

/// Handshake deadline: 10x the steady-state deadline, because the peer
/// regenerates and re-partitions the dataset between `Setup` and
/// `Ready`. `0` (deadlines off) stays 0.
fn handshake_ms(ft: &FaultToleranceConfig) -> u64 {
    ft.io_timeout_ms.saturating_mul(10)
}

fn backoff_ms(ft: &FaultToleranceConfig, attempt: usize) -> u64 {
    ft.backoff_base_ms
        .saturating_mul(1u64 << attempt.min(20))
        .min(ft.backoff_cap_ms)
}

/// Accept one connection within `ms` milliseconds (`0` = block
/// forever). The listener is polled non-blockingly so a worker that
/// never comes up yields a named [`Error::Timeout`], not a hang.
fn accept_with_deadline(listener: &TcpListener, ms: u64) -> Result<TcpStream> {
    if ms == 0 {
        let (stream, _) = listener.accept()?;
        return Ok(stream);
    }
    listener.set_nonblocking(true)?;
    let deadline = std::time::Instant::now() + Duration::from_millis(ms);
    let res = loop {
        match listener.accept() {
            Ok((stream, _)) => break Ok(stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if std::time::Instant::now() >= deadline {
                    break Err(Error::Timeout(format!(
                        "accepting a worker connection: deadline expired after {ms} ms"
                    )));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Err(e.into()),
        }
    };
    listener.set_nonblocking(false)?;
    if let Ok(stream) = &res {
        // Accepted sockets are blocking on every platform we support,
        // but be explicit — the deadline machinery assumes it.
        stream.set_nonblocking(false)?;
    }
    res
}

/// Write a checkpoint via temp-file-then-rename so a leader killed
/// mid-write can never leave a torn file where the resume path expects
/// a valid [`TrainState`].
fn write_checkpoint_atomic(path: &str, state: &TrainState) -> Result<()> {
    let bytes = state_to_bytes(state);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Worker-side knobs. The default is a plain worker; tests inject
/// faults through it.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Fault injection: after this many partition training steps the
    /// worker exits without replying, so the leader observes exactly
    /// what a crashed worker looks like — a closed socket mid-epoch.
    pub fail_after_steps: Option<usize>,
    /// Fault injection: when `steps_done` reaches this count the worker
    /// sleeps [`stall_ms`](Self::stall_ms) **once** before continuing —
    /// a hung-but-alive worker whose socket stays open, exercising the
    /// leader's suspect/declare-dead path rather than its dead-socket
    /// path.
    pub stall_after_steps: Option<usize>,
    /// How long the injected stall sleeps (bounded, so tests can always
    /// join the worker thread).
    pub stall_ms: u64,
    /// Deterministic fault schedule applied to this worker's outgoing
    /// frames (see [`chaos`]). Worker *processes* are armed through the
    /// `IEXACT_CHAOS` env var instead (`main.rs` maps it here).
    pub chaos: Option<chaos::ChaosSchedule>,
    /// Deadline for the `Setup` wait after connecting; `0` blocks
    /// forever. Steady-state reads stay deadline-free — a worker's
    /// liveness signal is the leader's socket, and a dead leader is an
    /// EOF, not a timeout.
    pub setup_timeout_ms: u64,
    /// Announce `Rejoin{rank}` instead of `Hello{rank}`: this worker
    /// replaces a dead one mid-run and expects a `Setup` carrying
    /// `plans_from`.
    pub rejoin: bool,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            fail_after_steps: None,
            stall_after_steps: None,
            stall_ms: 0,
            chaos: None,
            setup_timeout_ms: 30_000,
            rejoin: false,
        }
    }
}

/// Drop guard over spawned worker processes: however the leader exits
/// — clean return, error, or panic — no child outlives it.
///
/// The pre-PR-10 leader killed children only on its error *return*
/// path, so a leader panic (or an early `?`) stranded workers blocked
/// on their sockets forever. Owning the children in a guard makes the
/// cleanup unconditional; [`wait_all`](Self::wait_all) is the polite
/// exit for runs that ended well.
#[derive(Default)]
pub struct ChildReaper {
    children: Vec<std::process::Child>,
}

impl ChildReaper {
    pub fn new() -> Self {
        ChildReaper::default()
    }

    pub fn push(&mut self, child: std::process::Child) {
        self.children.push(child);
    }

    pub fn len(&self) -> usize {
        self.children.len()
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Give every child `grace` to exit on its own (they were just told
    /// to shut down), then kill and reap whatever is left. Never blocks
    /// longer than `grace` plus reaping time — a hung worker cannot
    /// wedge the leader's exit.
    pub fn wait_all(&mut self, grace: Duration) {
        let deadline = std::time::Instant::now() + grace;
        while !self.children.is_empty() && std::time::Instant::now() < deadline {
            self.children
                .retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
            if self.children.is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        self.kill_all();
    }

    /// Kill and reap every remaining child (idempotent: killing an
    /// already-exited child is a no-op, and waiting reaps the zombie).
    fn kill_all(&mut self) {
        for child in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

impl Drop for ChildReaper {
    fn drop(&mut self) {
        self.kill_all();
    }
}

/// Halo/eval traffic accounting: what actually crossed process
/// boundaries (packed codes + plan headers) vs. what shipping dense
/// `f32` activations would have cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireStats {
    /// Bytes of packed eval bodies received by the leader.
    pub halo_payload_bytes: u64,
    /// Bytes the same activations would occupy as dense `f32`.
    pub halo_f32_bytes: u64,
}

/// Supervision tally: what the fault-tolerance layer observed and did
/// during a run (all zero in a healthy run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultEvents {
    /// Read deadlines that expired (each marks a worker *suspect* and
    /// retries; several may belong to one eventual death).
    pub timeouts: u64,
    /// Heartbeat probes whose ack never arrived.
    pub heartbeat_misses: u64,
    /// Workers declared dead (socket closed, or retries exhausted).
    pub deaths: u64,
    /// Workers successfully restarted and rejoined mid-run.
    pub restarts: u64,
}

/// What a distributed run hands back: the single-process-identical
/// metrics/state plus wire accounting and the fault-recovery tally.
#[derive(Debug, Clone)]
pub struct DistTrainOutcome {
    /// Same shape (and bit-identical content) as single-process
    /// [`train_partitioned`](crate::pipeline::train_partitioned).
    pub result: PartitionTrainResult,
    /// End-of-run state; byte-identical under
    /// [`state_to_bytes`](crate::checkpoint::state_to_bytes) to the
    /// single-process run's.
    pub state: TrainState,
    pub wire: WireStats,
    /// Partitions re-dispatched to a surviving worker after their
    /// original owner died (0 in a healthy run).
    pub reassigned_partitions: usize,
    /// What the supervisor observed and did (see [`FaultEvents`]).
    pub faults: FaultEvents,
}

/// Leader-side integration hooks for elastic worker restart.
///
/// The leader itself has no idea how workers come into existence — the
/// caller spawned them (processes in production, threads in tests) —
/// so restarting one is delegated back through `respawn`. With no hook
/// (the default), a dead worker stays dead and its partitions are
/// simply reassigned.
#[derive(Default)]
pub struct DistHooks<'a> {
    /// Start a replacement worker for `rank`, pointed at the same
    /// leader address, with [`WorkerOptions::rejoin`] set. The hook
    /// only *launches* it; the leader handles the `Rejoin` handshake.
    #[allow(clippy::type_complexity)]
    pub respawn: Option<Box<dyn FnMut(u32) -> Result<()> + 'a>>,
}

struct WorkerLink {
    rank: u32,
    conn: FrameConn,
    alive: bool,
}

/// The leader's view of its worker fleet plus the fault-tolerance
/// machinery: deadline-aware reads with suspect/retry, heartbeats, and
/// declare-dead → restart.
struct Supervisor<'a> {
    links: Vec<WorkerLink>,
    listener: &'a TcpListener,
    ft: FaultToleranceConfig,
    hooks: DistHooks<'a>,
    events: FaultEvents,
    restarts_used: usize,
    nonce: u64,
}

impl<'a> Supervisor<'a> {
    /// Read one message from worker `w`, retrying expired deadlines up
    /// to `max_retries` times with capped exponential backoff (the
    /// frame layer resumes partial reads, so a retry continues the same
    /// frame). The final failure is returned as `Error::Timeout` naming
    /// the worker; the caller decides whether that is fatal or a death.
    fn read_retry(&mut self, w: usize) -> Result<Msg> {
        let mut attempt = 0;
        loop {
            match recv(&mut self.links[w].conn) {
                Err(Error::Timeout(m)) => {
                    self.events.timeouts += 1;
                    if attempt >= self.ft.max_retries {
                        return Err(Error::Timeout(format!(
                            "worker {} declared dead: {m} ({} suspect retries exhausted)",
                            self.links[w].rank, self.ft.max_retries
                        )));
                    }
                    // Suspect: back off, then resume the same read.
                    std::thread::sleep(Duration::from_millis(backoff_ms(&self.ft, attempt)));
                    attempt += 1;
                }
                other => return other,
            }
        }
    }

    /// Probe every live worker with `Heartbeat` and wait for the
    /// matching ack. A missed ack (deadline exhausted or closed socket)
    /// declares the worker dead — partitions reassign at the next
    /// dispatch; a *wrong* ack is a confused peer and fatal.
    fn heartbeat(&mut self, setup: &proto::WorkerSetup) -> Result<()> {
        for w in 0..self.links.len() {
            if !self.links[w].alive {
                continue;
            }
            self.nonce += 1;
            let nonce = self.nonce;
            if send(&mut self.links[w].conn, &Msg::Heartbeat { nonce }).is_err() {
                self.events.heartbeat_misses += 1;
                self.declare_dead(w, setup);
                continue;
            }
            match self.read_retry(w) {
                Ok(Msg::HeartbeatAck { nonce: n }) if n == nonce => {}
                Ok(Msg::HeartbeatAck { nonce: n }) => {
                    return Err(proto_err(format!(
                        "worker {} acked heartbeat nonce {n}, probe was {nonce}",
                        self.links[w].rank
                    )));
                }
                Ok(Msg::Abort { reason }) => {
                    return Err(proto_err(format!(
                        "worker {} aborted: {reason}",
                        self.links[w].rank
                    )));
                }
                Ok(other) => {
                    return Err(proto_err(format!(
                        "expected HeartbeatAck from worker {}, got {}",
                        self.links[w].rank,
                        other.kind()
                    )));
                }
                Err(Error::Io(_)) | Err(Error::Timeout(_)) => {
                    self.events.heartbeat_misses += 1;
                    self.declare_dead(w, setup);
                }
                Err(other) => return Err(other),
            }
        }
        Ok(())
    }

    /// Mark worker `w` dead and attempt an elastic restart if a respawn
    /// hook is installed and the restart budget allows. A failed
    /// restart consumes budget and leaves the rank dead (partitions
    /// reassign to survivors) — restart is an optimization, never a
    /// correctness requirement.
    fn declare_dead(&mut self, w: usize, setup: &proto::WorkerSetup) {
        self.links[w].alive = false;
        self.events.deaths += 1;
        let rank = self.links[w].rank;
        if self.hooks.respawn.is_none() || self.restarts_used >= self.ft.max_restarts {
            return;
        }
        self.restarts_used += 1;
        if let Err(e) = self.hooks.respawn.as_mut().expect("checked above")(rank) {
            eprintln!("[dist] failed to respawn worker {rank}: {e} (rank stays dead)");
            return;
        }
        match self.admit_rejoin(rank, setup) {
            Ok(conn) => {
                self.links[w].conn = conn;
                self.links[w].alive = true;
                self.events.restarts += 1;
            }
            Err(e) => {
                eprintln!("[dist] worker {rank} rejoin failed: {e} (rank stays dead)");
            }
        }
    }

    /// Accept the restarted worker's connection and run the rejoin
    /// handshake: `Rejoin{rank}` in, `Setup` (with `plans_from`) out,
    /// `Ready` fingerprint check, then steady-state deadlines.
    fn admit_rejoin(&mut self, rank: u32, setup: &proto::WorkerSetup) -> Result<FrameConn> {
        let hs = handshake_ms(&self.ft);
        let stream = accept_with_deadline(self.listener, hs)?;
        stream.set_nodelay(true)?;
        let mut conn = FrameConn::new(stream, format!("worker {rank} (rejoining)"));
        conn.set_deadline_ms(hs)?;
        match recv(&mut conn)? {
            Msg::Rejoin { rank: r } if r == rank => {}
            Msg::Rejoin { rank: r } => {
                return Err(proto_err(format!(
                    "rejoining worker announced rank {r}, expected {rank}"
                )));
            }
            other => {
                return Err(proto_err(format!(
                    "expected Rejoin from restarted worker {rank}, got {}",
                    other.kind()
                )));
            }
        }
        send(&mut conn, &Msg::Setup(Box::new(setup.clone())))?;
        match recv(&mut conn)? {
            Msg::Ready { fingerprint } if fingerprint == setup.ownership_fingerprint => {}
            Msg::Ready { fingerprint } => {
                return Err(proto_err(format!(
                    "rejoined worker {rank} partitioning fingerprint {fingerprint:#018x} \
                     disagrees with leader {:#018x}",
                    setup.ownership_fingerprint
                )));
            }
            Msg::Abort { reason } => {
                return Err(proto_err(format!(
                    "worker {rank} aborted during rejoin: {reason}"
                )));
            }
            other => {
                return Err(proto_err(format!(
                    "expected Ready from rejoined worker {rank}, got {}",
                    other.kind()
                )));
            }
        }
        conn.set_deadline_ms(self.ft.io_timeout_ms)?;
        conn.set_label(format!("worker {rank}"));
        Ok(conn)
    }
}

/// Accept exactly `n` workers and index them by their announced rank.
/// Handshake reads run at the relaxed handshake deadline; handshake
/// failures (including timeouts) are fatal — the fleet either comes up
/// whole or the run does not start.
fn accept_workers(
    listener: &TcpListener,
    n: usize,
    ft: &FaultToleranceConfig,
) -> Result<Vec<WorkerLink>> {
    let hs = handshake_ms(ft);
    let mut links: Vec<Option<WorkerLink>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let stream = accept_with_deadline(listener, hs)?;
        stream.set_nodelay(true)?;
        let mut conn = FrameConn::new(stream, "connecting worker");
        conn.set_deadline_ms(hs)?;
        match recv(&mut conn)? {
            Msg::Hello { rank } => {
                let r = rank as usize;
                if r >= n {
                    return Err(proto_err(format!(
                        "worker rank {rank} out of range (expected 0..{n})"
                    )));
                }
                if links[r].is_some() {
                    return Err(proto_err(format!("duplicate worker rank {rank}")));
                }
                conn.set_label(format!("worker {rank}"));
                links[r] = Some(WorkerLink {
                    rank,
                    conn,
                    alive: true,
                });
            }
            other => {
                return Err(proto_err(format!("expected Hello, got {}", other.kind())));
            }
        }
    }
    Ok(links
        .into_iter()
        .map(|l| l.expect("every rank connected exactly once"))
        .collect())
}

/// Scatter one request per partition over the live workers and gather
/// one parsed response per partition, **re-dispatching the partitions
/// of any worker that dies** (send or receive I/O error, or a read
/// deadline whose suspect retries exhaust) until every partition has a
/// result or no worker survives. Each death runs through the
/// supervisor's restart path, so a re-spawned worker can rejoin and
/// absorb pending partitions in the very same dispatch.
///
/// Correct because every request is a pure function of its partition
/// index and the epoch-start weights: recomputing a dead worker's
/// partition elsewhere yields bit-identical results. Named protocol
/// errors (garbage frames, aborts, mismatched replies) are fatal —
/// only *dead* peers are survivable, confused ones are not.
fn dispatch<T>(
    sup: &mut Supervisor<'_>,
    setup: &proto::WorkerSetup,
    k: usize,
    reassigned: &mut usize,
    make: impl Fn(Vec<u64>) -> Msg,
    mut parse: impl FnMut(Msg, usize) -> Result<T>,
) -> Result<Vec<T>> {
    let mut out: Vec<Option<T>> = (0..k).map(|_| None).collect();
    let mut first_round = true;
    loop {
        let pending: Vec<usize> = (0..k).filter(|&p| out[p].is_none()).collect();
        if pending.is_empty() {
            break;
        }
        let alive: Vec<usize> = sup
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .map(|(i, _)| i)
            .collect();
        if alive.is_empty() {
            return Err(proto_err(format!(
                "all {} workers are dead with {} partition results outstanding",
                sup.links.len(),
                pending.len()
            )));
        }
        if !first_round {
            *reassigned += pending.len();
        }
        first_round = false;
        // Round-robin the pending partitions over the live workers —
        // with all workers alive this is the static p % N assignment.
        let mut rounds: Vec<Vec<u64>> = vec![Vec::new(); sup.links.len()];
        for (i, &p) in pending.iter().enumerate() {
            rounds[alive[i % alive.len()]].push(p as u64);
        }
        // Write every request before reading any response: workers
        // proceed independently, so the leader never deadlocks waiting
        // on a worker that is itself waiting to be asked.
        for (w, parts) in rounds.iter().enumerate() {
            if parts.is_empty() {
                continue;
            }
            if send(&mut sup.links[w].conn, &make(parts.clone())).is_err() {
                // A write timeout left a partial frame on the socket —
                // unlike reads it cannot be resumed, so either way the
                // worker is dead to us.
                sup.declare_dead(w, setup);
            }
        }
        for (w, parts) in rounds.iter().enumerate() {
            if parts.is_empty() || !sup.links[w].alive {
                continue;
            }
            for &p in parts {
                match sup.read_retry(w) {
                    Ok(Msg::Abort { reason }) => {
                        return Err(proto_err(format!(
                            "worker {} aborted: {reason}",
                            sup.links[w].rank
                        )));
                    }
                    Ok(msg) => {
                        out[p as usize] = Some(parse(msg, p as usize)?);
                    }
                    Err(Error::Io(_)) | Err(Error::Timeout(_)) => {
                        // Dead (or hopelessly hung) worker: everything
                        // it still owed goes back into the pool for the
                        // next round; the restart path may already have
                        // revived the rank.
                        sup.declare_dead(w, setup);
                        break;
                    }
                    Err(other) => return Err(other),
                }
            }
        }
    }
    Ok(out
        .into_iter()
        .map(|o| o.expect("loop exits only with every partition resolved"))
        .collect())
}

/// Drive a distributed training run as the **leader**: accept
/// `cfg.distributed.workers` connections on `listener`, hand each
/// worker the training context, and run the partitioned trainer's
/// epoch loop with all partition steps computed remotely.
///
/// The run is **bit-identical** to single-process
/// [`train_partitioned`](crate::pipeline::train_partitioned) on the
/// same `(spec, dataset_seed, quant, cfg, seed)` at any worker count —
/// same loss curve, same final weights, byte-identical
/// [`state_to_bytes`](crate::checkpoint::state_to_bytes) image — and
/// survives worker deaths by re-dispatching their partitions (see
/// [`DistTrainOutcome::reassigned_partitions`]). With
/// `cfg.distributed.checkpoint_path` set, a [`TrainState`] is written
/// atomically every `checkpoint_every_epochs`; pass a loaded state as
/// `resume` to continue a killed run with the identical trajectory.
///
/// The caller owns process management: bind the listener, spawn the
/// worker processes (or threads, in tests) pointed at its address,
/// then call this. Equivalent to
/// [`train_distributed_with`] with no restart hook — dead workers stay
/// dead and their partitions reassign.
pub fn train_distributed(
    listener: &TcpListener,
    spec: &DatasetSpec,
    dataset_seed: u64,
    quant: &QuantConfig,
    cfg: &TrainConfig,
    seed: u64,
    resume: Option<TrainState>,
) -> Result<DistTrainOutcome> {
    train_distributed_with(
        listener,
        spec,
        dataset_seed,
        quant,
        cfg,
        seed,
        resume,
        DistHooks::default(),
    )
}

/// [`train_distributed`] plus leader-side [`DistHooks`]: with a
/// `respawn` hook installed, a worker declared dead is re-spawned
/// (bounded by `[fault_tolerance] max_restarts`), re-admitted through
/// the `Rejoin` handshake and re-`Setup` mid-run — with the epoch
/// results still bit-identical to an uninterrupted run.
#[allow(clippy::too_many_arguments)]
pub fn train_distributed_with(
    listener: &TcpListener,
    spec: &DatasetSpec,
    dataset_seed: u64,
    quant: &QuantConfig,
    cfg: &TrainConfig,
    seed: u64,
    resume: Option<TrainState>,
    hooks: DistHooks<'_>,
) -> Result<DistTrainOutcome> {
    quant.validate()?;
    cfg.validate()?;
    let dcfg = &cfg.distributed;
    if !dcfg.enabled() {
        return Err(Error::Config(
            "train_distributed requires distributed.workers >= 1".into(),
        ));
    }
    let ft = cfg.fault_tolerance.clone();
    let dataset = spec.generate(dataset_seed);
    dataset.validate()?;
    let pcfg = &cfg.partition;
    let k = pcfg.num_partitions;
    let parts = partition_dataset(&dataset, k, pcfg.halo_hops)?;
    let fingerprint = HaloOwnership::build(&parts)?.fingerprint();
    let core_train_counts: Vec<usize> = parts.parts.iter().map(|p| p.core_train_count()).collect();
    let total_train: usize = core_train_counts.iter().sum();
    if total_train == 0 {
        return Err(Error::Config("dataset has no training nodes".into()));
    }
    let halo_nodes = parts.total_halo_nodes();
    let edge_cut_fraction = parts.edge_cut_fraction();
    // Scatter metadata for eval assembly; the subgraphs themselves live
    // on the workers, so the leader drops the partition set entirely.
    let assembly: Vec<(Vec<usize>, Vec<bool>)> = parts
        .parts
        .iter()
        .map(|p| (p.node_map.clone(), p.core_mask.clone()))
        .collect();
    drop(parts);

    let (start_epoch, mut model, mut adam, rng) =
        init_partitioned_run(&dataset, quant, cfg, seed, resume)?;

    let mut sup = Supervisor {
        links: accept_workers(listener, dcfg.workers, &ft)?,
        listener,
        ft: ft.clone(),
        hooks,
        events: FaultEvents::default(),
        restarts_used: 0,
        nonce: 0,
    };
    let adaptive = cfg.allocation.allocator(quant)?.is_some();
    let mut setup = proto::WorkerSetup {
        spec: spec.clone(),
        dataset_seed,
        seed,
        quant: quant.clone(),
        arch: cfg.arch,
        hidden_dim: cfg.hidden_dim,
        num_layers: cfg.num_layers,
        num_partitions: k,
        halo_hops: pcfg.halo_hops,
        cache_bits: pcfg.cache_bits,
        allocation: cfg.allocation.clone(),
        ownership_fingerprint: fingerprint,
        plans_from: None,
    };
    for w in 0..sup.links.len() {
        send(&mut sup.links[w].conn, &Msg::Setup(Box::new(setup.clone())))?;
    }
    for w in 0..sup.links.len() {
        let rank = sup.links[w].rank;
        match recv(&mut sup.links[w].conn)? {
            Msg::Ready { fingerprint: fp } if fp == fingerprint => {}
            Msg::Ready { fingerprint: fp } => {
                return Err(proto_err(format!(
                    "worker {rank} partitioning fingerprint {fp:#018x} disagrees with \
                     leader {fingerprint:#018x}"
                )));
            }
            Msg::Abort { reason } => {
                return Err(proto_err(format!(
                    "worker {rank} aborted during handshake: {reason}"
                )));
            }
            other => {
                return Err(proto_err(format!(
                    "expected Ready from worker {rank}, got {}",
                    other.kind()
                )));
            }
        }
        // Handshake survived: drop to the steady-state deadline.
        sup.links[w].conn.set_deadline_ms(ft.io_timeout_ms)?;
    }

    let engine = QuantEngine::from_config(&cfg.parallelism);
    let mut pool = BufferPool::new();
    let mut cache = ActivationCache::new(k, seed ^ 0x00ca_c4ed);

    let mut curve = TrainCurve::default();
    let mut timer = LapTimer::new();
    let mut best_val_loss = f64::INFINITY;
    let mut test_at_best = 0.0;
    let mut max_stash = 0usize;
    let mut peak_resident = 0usize;
    let mut final_train_loss = f64::NAN;
    let mut wire = WireStats::default();
    let mut reassigned = 0usize;
    let n = dataset.num_nodes();

    for epoch in start_epoch..cfg.epochs {
        let t0 = std::time::Instant::now();
        // Keep the rejoin context current *before* any fault can strike
        // this epoch: at a realloc boundary the workers re-solve their
        // bit plans from these exact weights, so a worker restarted any
        // time before the next boundary must re-solve from them too.
        if adaptive && epoch % cfg.allocation.realloc_interval_epochs == 0 {
            setup.plans_from = Some((epoch as u64, model.weights.clone()));
        }
        if ft.heartbeat_every_epochs > 0 && epoch % ft.heartbeat_every_epochs == 0 {
            sup.heartbeat(&setup)?;
        }
        let steps = dispatch(
            &mut sup,
            &setup,
            k,
            &mut reassigned,
            |parts| Msg::Steps {
                epoch: epoch as u64,
                parts,
                weights: model.weights.clone(),
            },
            |msg, p| match msg {
                Msg::StepResult {
                    part,
                    loss,
                    stash_bytes,
                    grads,
                } if part as usize == p => Ok((loss, stash_bytes as usize, grads)),
                other => Err(proto_err(format!(
                    "expected StepResult for partition {p}, got {}",
                    other.kind()
                ))),
            },
        )?;
        // Fold in fixed partition order p = 0..k — the dispatch order
        // and worker count cannot leak into the accumulated gradient.
        let mut grad_acc: Vec<Matrix> = model
            .shapes()
            .iter()
            .map(|&(r, c)| Matrix::zeros(r, c))
            .collect();
        let mut loss_acc = 0.0f64;
        for (p, (loss, stash, grads)) in steps.into_iter().enumerate() {
            if grads.len() != grad_acc.len() {
                return Err(proto_err(format!(
                    "partition {p} returned {} gradient matrices, expected {}",
                    grads.len(),
                    grad_acc.len()
                )));
            }
            let w = core_train_counts[p] as f64 / total_train as f64;
            loss_acc += loss * w;
            for (a, g) in grad_acc.iter_mut().zip(&grads) {
                a.axpy(w as f32, g)?;
            }
            max_stash = max_stash.max(stash);
        }
        adam.step(&mut model.weights, &grad_acc)?;
        final_train_loss = loss_acc;

        if epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs {
            let bodies = dispatch(
                &mut sup,
                &setup,
                k,
                &mut reassigned,
                |parts| Msg::Evals {
                    epoch: epoch as u64,
                    parts,
                    weights: model.weights.clone(),
                },
                |msg, p| match msg {
                    Msg::EvalResult { part, body } if part as usize == p => Ok(body),
                    other => Err(proto_err(format!(
                        "expected EvalResult for partition {p}, got {}",
                        other.kind()
                    ))),
                },
            )?;
            // Packed logits park straight into the cache — the wire body
            // *is* the cache entry, quantized on the worker under the
            // same slot seed stream a local park would use.
            for (p, body) in bodies.into_iter().enumerate() {
                wire.halo_payload_bytes += body.len() as u64;
                let pt = engine.decode_from_wire(&body, &mut pool)?;
                wire.halo_f32_bytes += (pt.shape.0 * pt.shape.1 * 4) as u64;
                cache.park_packed(p, pt, &mut pool)?;
            }
            peak_resident = peak_resident.max(cache.resident_bytes());
            let mut full = Matrix::zeros(n, dataset.num_classes);
            for (p, (node_map, core_mask)) in assembly.iter().enumerate() {
                let deq = cache
                    .fetch(p, &engine, &mut pool)?
                    .expect("parked in the loop above");
                for (local, &parent) in node_map.iter().enumerate() {
                    if core_mask[local] {
                        full.row_mut(parent).copy_from_slice(deq.row(local));
                    }
                }
                pool.put_floats(deq.into_vec());
            }
            let (val_loss, _) = softmax_cross_entropy(&full, &dataset.labels, &dataset.val_mask)?;
            let val_acc = masked_accuracy(&full, &dataset.labels, &dataset.val_mask);
            curve.push(epoch, loss_acc, val_loss, val_acc);
            if val_loss < best_val_loss {
                best_val_loss = val_loss;
                test_at_best = masked_accuracy(&full, &dataset.labels, &dataset.test_mask);
            }
        }

        if let Some(path) = &dcfg.checkpoint_path {
            let done = epoch + 1;
            if done % dcfg.checkpoint_every_epochs == 0 || done == cfg.epochs {
                let st = TrainState {
                    epoch: done,
                    model: model.clone(),
                    adam: adam.clone(),
                    rng: rng.clone(),
                    plans: None,
                };
                write_checkpoint_atomic(path, &st)?;
            }
        }
        timer.record(t0.elapsed());
    }

    // Best-effort: a worker that already died is already accounted for.
    for link in &mut sup.links {
        if link.alive {
            let _ = send(&mut link.conn, &Msg::Shutdown);
        }
    }

    let state = TrainState {
        epoch: cfg.epochs,
        model: model.clone(),
        adam,
        rng,
        plans: None,
    };
    Ok(DistTrainOutcome {
        result: PartitionTrainResult {
            result: TrainResult {
                test_accuracy: test_at_best,
                best_val_loss,
                curve,
                epochs_per_sec: timer.rate_per_sec(),
                stash_bytes: max_stash,
                final_train_loss,
            },
            peak_resident_bytes: peak_resident,
            cache_bytes: cache.resident_bytes() + cache.spilled_bytes(),
            num_partitions: k,
            halo_nodes,
            edge_cut_fraction,
            model,
        },
        state,
        wire,
        reassigned_partitions: reassigned,
        faults: sup.events,
    })
}

/// Run one **worker**: connect to the leader at `addr`, announce
/// `rank` (via `Hello`, or `Rejoin` for a restarted worker), rebuild
/// the training context from the Setup message (regenerating the
/// dataset and re-partitioning locally), then serve step/eval/heartbeat
/// requests until Shutdown.
///
/// All compute goes through the same `partition_train_step` /
/// `pack_partition_logits` kernels as the single-process trainer, on a
/// serial [`QuantEngine`] — results are bit-identical at any thread
/// count anyway, and worker processes already are the parallelism.
/// Eval replies carry the partition's logits as packed codes, never
/// dense `f32`.
///
/// An injected chaos crash (`drop`/`trunc` faults from
/// [`WorkerOptions::chaos`]) exits with `Ok(())`, exactly like the
/// `fail_after_steps` injection — from the outside both look like a
/// cleanly crashed process.
pub fn run_worker(addr: &str, rank: u32, opts: &WorkerOptions) -> Result<()> {
    match run_worker_inner(addr, rank, opts) {
        Err(e) if chaos::is_chaos_kill(&e) => Ok(()),
        other => other,
    }
}

fn run_worker_inner(addr: &str, rank: u32, opts: &WorkerOptions) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut conn = FrameConn::new(stream, "leader");
    if let Some(schedule) = &opts.chaos {
        conn.set_chaos(chaos::ChaosState::new(rank, schedule.clone()));
    }
    let hello = if opts.rejoin {
        Msg::Rejoin { rank }
    } else {
        Msg::Hello { rank }
    };
    send(&mut conn, &hello)?;
    // Only the Setup wait carries a deadline: a leader that accepts a
    // connection but never ships context is indistinguishable from a
    // hang. Past Setup, a dead leader is a closed socket (EOF), so
    // steady-state reads block without deadlines.
    conn.set_deadline_ms(opts.setup_timeout_ms)?;
    let setup = match recv(&mut conn).map_err(|e| match e {
        Error::Timeout(m) => Error::Timeout(format!("worker {rank} waiting for Setup: {m}")),
        other => other,
    })? {
        Msg::Setup(s) => *s,
        Msg::Abort { reason } => {
            return Err(proto_err(format!("leader aborted: {reason}")));
        }
        other => {
            return Err(proto_err(format!("expected Setup, got {}", other.kind())));
        }
    };
    conn.set_deadline_ms(0)?;
    let dataset = setup.spec.generate(setup.dataset_seed);
    dataset.validate()?;
    let k = setup.num_partitions;
    let parts = partition_dataset(&dataset, k, setup.halo_hops)?;
    let fingerprint = HaloOwnership::build(&parts)?.fingerprint();
    if fingerprint != setup.ownership_fingerprint {
        // Training on a divergent partitioning would silently corrupt
        // the run; tell the leader why before bailing.
        let reason = format!(
            "worker {rank} partitioning fingerprint {fingerprint:#018x} disagrees \
             with leader {:#018x}",
            setup.ownership_fingerprint
        );
        let _ = send(
            &mut conn,
            &Msg::Abort {
                reason: reason.clone(),
            },
        );
        return Err(proto_err(reason));
    }
    send(&mut conn, &Msg::Ready { fingerprint })?;

    let bins = resolve_layer_bins(
        setup.arch,
        dataset.num_features(),
        setup.hidden_dim,
        dataset.num_classes,
        setup.num_layers,
        &setup.quant,
    )?;
    let allocator = setup.allocation.allocator(&setup.quant)?;
    let engine = QuantEngine::serial();
    let mut pool = BufferPool::new();
    let mut plans: Vec<Option<Vec<BitPlan>>> = vec![None; k];
    let mut plans_epoch: Option<u64> = None;
    let mut steps_done = 0usize;

    // Rejoin context: re-solve every partition's plans from the last
    // realloc epoch's weights, exactly as the surviving workers did at
    // that epoch — the stats streams are (epoch, partition)-addressed,
    // so the solve lands bit-identical wherever (and whenever) it runs.
    if let (Some(alloc), Some((e0, w0))) = (&allocator, &setup.plans_from) {
        let model = GcnModel {
            arch: setup.arch,
            weights: w0.clone(),
        };
        let e = *e0 as usize;
        for (p, slot) in plans.iter_mut().enumerate() {
            let mut stats_rng = Pcg64::with_stream(setup.seed ^ 0xb17a_1710, (e * k + p) as u64);
            *slot = Some(allocate_plans(
                &model,
                &parts.parts[p].data,
                &setup.quant,
                alloc,
                &mut stats_rng,
            )?);
        }
        plans_epoch = Some(*e0);
    }

    loop {
        match recv(&mut conn)? {
            Msg::Steps {
                epoch,
                parts: assigned,
                weights,
            } => {
                let model = GcnModel {
                    arch: setup.arch,
                    weights,
                };
                if let Some(alloc) = &allocator {
                    let e = epoch as usize;
                    if e % setup.allocation.realloc_interval_epochs == 0
                        && plans_epoch != Some(epoch)
                    {
                        // Re-solve *all* k partitions' plans, not just
                        // this round's: a mid-epoch reassignment may hand
                        // this worker any partition, and the stats
                        // streams are (epoch, partition)-addressed so the
                        // solve is identical wherever it runs.
                        for (p, slot) in plans.iter_mut().enumerate() {
                            let mut stats_rng =
                                Pcg64::with_stream(setup.seed ^ 0xb17a_1710, (e * k + p) as u64);
                            *slot = Some(allocate_plans(
                                &model,
                                &parts.parts[p].data,
                                &setup.quant,
                                alloc,
                                &mut stats_rng,
                            )?);
                        }
                        plans_epoch = Some(epoch);
                    }
                }
                for &pu in &assigned {
                    let p = checked_part(pu, &parts)?;
                    if let Some(limit) = opts.fail_after_steps {
                        if steps_done >= limit {
                            // Fault injection: vanish without replying —
                            // the leader sees the closed socket, exactly
                            // like a crashed worker process.
                            return Ok(());
                        }
                    }
                    if opts.stall_after_steps == Some(steps_done) {
                        // Fault injection: hang with the socket open.
                        // Bounded so tests can always join the thread;
                        // the leader's deadline must fire first.
                        std::thread::sleep(Duration::from_millis(opts.stall_ms));
                    }
                    let (loss, grads, stash) = partition_train_step(
                        &model,
                        &parts.parts[p].data,
                        &setup.quant,
                        &bins,
                        plans[p].as_deref(),
                        setup.seed,
                        epoch as usize,
                        k,
                        p,
                        &engine,
                        &mut pool,
                    )?;
                    steps_done += 1;
                    send(
                        &mut conn,
                        &Msg::StepResult {
                            part: pu,
                            loss,
                            stash_bytes: stash as u64,
                            grads,
                        },
                    )?;
                }
            }
            Msg::Evals {
                epoch: _,
                parts: assigned,
                weights,
            } => {
                let model = GcnModel {
                    arch: setup.arch,
                    weights,
                };
                for &pu in &assigned {
                    let p = checked_part(pu, &parts)?;
                    let pt = pack_partition_logits(
                        &model,
                        &parts.parts[p].data,
                        setup.cache_bits,
                        setup.seed,
                        p,
                        &engine,
                        &mut pool,
                    )?;
                    let mut body = Vec::with_capacity(64 + pt.packed.len());
                    crate::memory::write_planned(&mut body, &pt);
                    pool.put_bytes(pt.packed);
                    send(&mut conn, &Msg::EvalResult { part: pu, body })?;
                }
            }
            Msg::Heartbeat { nonce } => {
                send(&mut conn, &Msg::HeartbeatAck { nonce })?;
            }
            Msg::Shutdown => return Ok(()),
            Msg::Abort { reason } => {
                return Err(proto_err(format!("leader aborted: {reason}")));
            }
            other => {
                return Err(proto_err(format!(
                    "unexpected {} message on a serving worker",
                    other.kind()
                )));
            }
        }
    }
}

fn checked_part(pu: u64, parts: &PartitionSet) -> Result<usize> {
    let p = pu as usize;
    if p >= parts.parts.len() {
        return Err(proto_err(format!(
            "leader assigned partition {p}, but only {} exist",
            parts.parts.len()
        )));
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Arch;
    use crate::linalg::Adam;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("iexact_dist_{name}_{}", std::process::id()))
    }

    #[test]
    fn checkpoint_write_is_atomic_and_loadable() {
        let mut rng = Pcg64::new(7);
        let model = GcnModel::init_arch(Arch::Gcn, 4, 8, 3, 2, &mut rng).unwrap();
        let adam = Adam::new(1e-2, 0.0, &model.shapes());
        let state = TrainState {
            epoch: 5,
            model,
            adam,
            rng,
            plans: None,
        };
        let path = tmp_path("atomic_ckpt");
        let path_str = path.to_str().unwrap().to_string();
        write_checkpoint_atomic(&path_str, &state).unwrap();
        // The temp file must not linger and the artifact must round-trip.
        assert!(!std::path::Path::new(&format!("{path_str}.tmp")).exists());
        let loaded = crate::checkpoint::load_state(&path).unwrap();
        assert_eq!(loaded.epoch, 5);
        assert_eq!(
            crate::checkpoint::state_to_bytes(&loaded),
            crate::checkpoint::state_to_bytes(&state)
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn backoff_grows_and_caps() {
        let ft = FaultToleranceConfig {
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            ..FaultToleranceConfig::default()
        };
        assert_eq!(backoff_ms(&ft, 0), 50);
        assert_eq!(backoff_ms(&ft, 1), 100);
        assert_eq!(backoff_ms(&ft, 3), 400);
        assert_eq!(backoff_ms(&ft, 10), 2_000);
        assert_eq!(backoff_ms(&ft, 63), 2_000); // shift is clamped, no overflow
    }

    #[test]
    fn accept_deadline_expires_as_named_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = accept_with_deadline(&listener, 50).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(err.to_string().contains("accepting a worker"), "{err}");
    }

    /// Regression for the leader error path: dropping the reaper (as an
    /// early `?` or a panic would) must kill and reap every child, not
    /// leave it running or zombied.
    #[test]
    #[cfg(target_os = "linux")]
    fn child_reaper_kills_on_drop() {
        let mut reaper = ChildReaper::new();
        let child = std::process::Command::new("sleep")
            .arg("30")
            .spawn()
            .expect("spawn sleep");
        let pid = child.id();
        reaper.push(child);
        assert_eq!(reaper.len(), 1);
        drop(reaper);
        // Killed AND waited: the pid is fully reaped, so /proc/<pid> is
        // gone (a zombie would still have an entry).
        assert!(
            !std::path::Path::new(&format!("/proc/{pid}")).exists(),
            "child {pid} survived the reaper drop"
        );
    }

    /// `wait_all` reaps children that exit within the grace period
    /// without killing, and never blocks past grace on one that won't.
    #[test]
    #[cfg(target_os = "linux")]
    fn child_reaper_wait_all_is_bounded() {
        let mut reaper = ChildReaper::new();
        let quick = std::process::Command::new("true").spawn().expect("spawn");
        let hung = std::process::Command::new("sleep")
            .arg("30")
            .spawn()
            .expect("spawn sleep");
        let hung_pid = hung.id();
        reaper.push(quick);
        reaper.push(hung);
        let t0 = std::time::Instant::now();
        reaper.wait_all(Duration::from_millis(300));
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "wait_all blocked on a hung child"
        );
        assert!(reaper.is_empty());
        assert!(
            !std::path::Path::new(&format!("/proc/{hung_pid}")).exists(),
            "hung child {hung_pid} was not killed after the grace period"
        );
    }
}
