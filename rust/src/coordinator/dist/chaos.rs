//! Deterministic chaos injection for the distributed runtime.
//!
//! A [`ChaosSchedule`] is a finite map from `(rank, message-index)` to
//! a [`Fault`], applied to that worker's **outgoing** frames (message
//! index 0 is its `Hello`/`Rejoin`, 1 its `Ready`, then one per
//! `StepResult`/`EvalResult`/heartbeat ack). Because every fault is
//! addressed, a chaos run is exactly reproducible: the same schedule
//! against the same config perturbs the same bytes of the same
//! messages, so `tests/chaos_dist.rs` can assert the strong property —
//! the run either completes with weights bit-identical to the
//! undisturbed run, or fails with a *named* error. Never a hang.
//!
//! Fault semantics (implemented in the frame layer,
//! [`FrameConn::write_frame`](super::frame::FrameConn)):
//!
//! * `drop` — the frame is never sent and the socket is severed: a
//!   simulated crash immediately before the send. (Dropping a single
//!   frame while keeping the connection would desync the epoch
//!   protocol rather than model any real failure.)
//! * `delay:MS` — the frame is sent after `MS` milliseconds: a hung
//!   but alive worker, exercising the leader's suspect/retry path.
//! * `trunc` — half the frame is sent, then the socket is severed: a
//!   crash mid-write, exercising the leader's short-read handling.
//! * `flip` — one payload bit is flipped and the frame sent normally:
//!   wire corruption, which the frame checksum must turn into a named
//!   protocol error.
//!
//! Schedules come from three places, in precedence order: the
//! `IEXACT_CHAOS` env var (wins, so a whole leader+workers process
//! tree can be armed externally), the `[fault_tolerance] chaos` config
//! key, or a [`WorkerOptions`](super::WorkerOptions) field for
//! in-process test workers.
//!
//! The spec grammar is `rank:index:kind[:ms]` events joined by `;`:
//!
//! ```text
//! IEXACT_CHAOS="1:4:drop;0:6:delay:250;1:3:trunc;0:5:flip"
//! ```

use crate::rngs::Pcg64;
use std::collections::BTreeMap;

/// Env var holding a chaos spec; overrides the config key.
pub const CHAOS_ENV: &str = "IEXACT_CHAOS";

/// Marker prefix for errors raised *by* an injected fault inside the
/// faulting worker (the peer sees a normal dead-peer error instead).
const KILL_MARKER: &str = "chaos fault injected";

/// One injected fault (see the module docs for wire semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Sever the connection instead of sending: a crash before send.
    Drop,
    /// Send the frame late: a hung-but-alive worker.
    Delay {
        /// How long the frame is held back.
        ms: u64,
    },
    /// Send half the frame, then sever: a crash mid-write.
    Truncate,
    /// Flip one payload bit and send: wire corruption.
    BitFlip,
}

impl Fault {
    fn spec_kind(&self) -> &'static str {
        match self {
            Fault::Drop => "drop",
            Fault::Delay { .. } => "delay",
            Fault::Truncate => "trunc",
            Fault::BitFlip => "flip",
        }
    }
}

/// A deterministic fault schedule addressed by `(rank, message-index)`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosSchedule {
    events: BTreeMap<(u32, u64), Fault>,
}

impl ChaosSchedule {
    /// Parse the `rank:index:kind[:ms]` grammar. Errors are plain
    /// strings so callers can prepend their own key path.
    pub fn parse(spec: &str) -> std::result::Result<Self, String> {
        let mut events = BTreeMap::new();
        for ev in spec.split(';').filter(|e| !e.trim().is_empty()) {
            let parts: Vec<&str> = ev.trim().split(':').collect();
            if parts.len() < 3 {
                return Err(format!(
                    "bad chaos event '{ev}': expected rank:index:kind[:ms]"
                ));
            }
            let rank: u32 = parts[0]
                .parse()
                .map_err(|_| format!("bad chaos event '{ev}': rank '{}'", parts[0]))?;
            let index: u64 = parts[1]
                .parse()
                .map_err(|_| format!("bad chaos event '{ev}': index '{}'", parts[1]))?;
            let fault = match (parts[2], parts.len()) {
                ("drop", 3) => Fault::Drop,
                ("trunc", 3) => Fault::Truncate,
                ("flip", 3) => Fault::BitFlip,
                ("delay", 4) => Fault::Delay {
                    ms: parts[3].parse().map_err(|_| {
                        format!("bad chaos event '{ev}': delay ms '{}'", parts[3])
                    })?,
                },
                ("delay", _) => {
                    return Err(format!("bad chaos event '{ev}': delay needs :ms"));
                }
                (kind, _) => {
                    return Err(format!(
                        "bad chaos event '{ev}': unknown kind '{kind}' \
                         (drop/delay/trunc/flip)"
                    ));
                }
            };
            if events.insert((rank, index), fault).is_some() {
                return Err(format!(
                    "duplicate chaos event for rank {rank} index {index}"
                ));
            }
        }
        Ok(ChaosSchedule { events })
    }

    /// Serialize back to the spec grammar (round-trips through
    /// [`parse`](Self::parse); used to arm child processes via env).
    pub fn to_spec(&self) -> String {
        self.events
            .iter()
            .map(|((rank, index), fault)| match fault {
                Fault::Delay { ms } => format!("{rank}:{index}:delay:{ms}"),
                f => format!("{rank}:{index}:{}", f.spec_kind()),
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// A seeded pseudo-random schedule: `events` faults drawn from
    /// `kinds`, spread over `ranks` workers at message indices in
    /// `2..2 + index_span` (0/1 are the handshake — faulting those just
    /// aborts the run before it starts, which is a different test).
    pub fn seeded(seed: u64, ranks: u32, events: usize, index_span: u64, kinds: &[Fault]) -> Self {
        assert!(ranks > 0 && !kinds.is_empty() && index_span > 0);
        let mut rng = Pcg64::new(seed ^ 0xc4a0_5000);
        let mut out = BTreeMap::new();
        let mut attempts = 0;
        while out.len() < events && attempts < events * 16 {
            attempts += 1;
            let rank = (rng.next_u64() % ranks as u64) as u32;
            let index = 2 + rng.next_u64() % index_span;
            let kind = kinds[(rng.next_u64() % kinds.len() as u64) as usize];
            let fault = match kind {
                Fault::Delay { .. } => Fault::Delay {
                    ms: 50 + rng.next_u64() % 250,
                },
                f => f,
            };
            out.entry((rank, index)).or_insert(fault);
        }
        ChaosSchedule { events: out }
    }

    /// Read the schedule from [`CHAOS_ENV`], if set.
    pub fn from_env() -> std::result::Result<Option<Self>, String> {
        match std::env::var(CHAOS_ENV) {
            Ok(spec) if !spec.is_empty() => Self::parse(&spec).map(Some),
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The fault scheduled for `rank`'s `index`-th outgoing frame.
    pub fn get(&self, rank: u32, index: u64) -> Option<Fault> {
        self.events.get(&(rank, index)).copied()
    }
}

/// A schedule bound to one worker's rank, attached to its
/// [`FrameConn`](super::frame::FrameConn).
#[derive(Debug, Clone)]
pub(crate) struct ChaosState {
    rank: u32,
    schedule: ChaosSchedule,
}

impl ChaosState {
    pub(crate) fn new(rank: u32, schedule: ChaosSchedule) -> Self {
        ChaosState { rank, schedule }
    }

    pub(crate) fn fault_at(&self, index: u64) -> Option<Fault> {
        self.schedule.get(self.rank, index)
    }
}

/// The error an injected `drop`/`trunc` fault raises inside the
/// faulting worker; [`is_chaos_kill`] recognizes it so the worker can
/// exit as cleanly as a real crash would.
pub(crate) fn kill_error(kind: &str, index: u64) -> crate::Error {
    crate::Error::Runtime(format!("{KILL_MARKER}: {kind} at frame {index}"))
}

/// Whether `e` is an injected-crash marker from [`kill_error`].
pub fn is_chaos_kill(e: &crate::Error) -> bool {
    matches!(e, crate::Error::Runtime(m) if m.starts_with(KILL_MARKER))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips() {
        let spec = "0:2:drop;0:5:flip;1:3:delay:250;1:4:trunc";
        let sched = ChaosSchedule::parse(spec).unwrap();
        assert_eq!(sched.len(), 4);
        assert_eq!(sched.get(1, 3), Some(Fault::Delay { ms: 250 }));
        assert_eq!(sched.get(0, 2), Some(Fault::Drop));
        assert_eq!(sched.get(0, 3), None);
        assert_eq!(sched.to_spec(), spec);
        assert_eq!(ChaosSchedule::parse(&sched.to_spec()).unwrap(), sched);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_offending_event() {
        for (spec, needle) in [
            ("1:2", "rank:index:kind"),
            ("x:2:drop", "rank 'x'"),
            ("1:y:drop", "index 'y'"),
            ("1:2:explode", "unknown kind 'explode'"),
            ("1:2:delay", "delay needs :ms"),
            ("1:2:delay:zz", "delay ms 'zz'"),
            ("1:2:drop;1:2:flip", "duplicate"),
        ] {
            let err = ChaosSchedule::parse(spec).unwrap_err();
            assert!(err.contains(needle), "spec '{spec}': {err}");
        }
    }

    #[test]
    fn seeded_schedules_are_deterministic_and_skip_the_handshake() {
        let kinds = [Fault::Drop, Fault::Delay { ms: 0 }, Fault::Truncate];
        let a = ChaosSchedule::seeded(7, 2, 5, 10, &kinds);
        let b = ChaosSchedule::seeded(7, 2, 5, 10, &kinds);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        for ((rank, index), _) in &a.events {
            assert!(*rank < 2);
            assert!((2..12).contains(index), "index {index} hits the handshake");
        }
        // A different seed draws a different schedule.
        let c = ChaosSchedule::seeded(8, 2, 5, 10, &kinds);
        assert_ne!(a, c);
    }

    #[test]
    fn kill_marker_is_recognizable() {
        let e = kill_error("drop", 4);
        assert!(is_chaos_kill(&e));
        assert!(e.to_string().contains("drop at frame 4"));
        assert!(!is_chaos_kill(&crate::Error::Runtime("other".into())));
    }
}
