//! Message layer of the distributed protocol: everything that travels
//! inside a [`frame`](super::frame) payload.
//!
//! Encoding rides the checkpoint module's little-endian write helpers
//! and bounds-checked [`Reader`], so the wire format shares its idioms
//! (and its truncation diagnostics) with every on-disk format in the
//! crate. Matrices use the exact checkpoint layout; the `EvalResult`
//! body is a [`crate::memory::write_planned`] image — the same bytes an
//! out-of-core spill file holds after its slot field.

use crate::checkpoint::{write_matrix, write_u32, write_u64, Reader};
use crate::config::{AllocStrategy, AllocationConfig, Arch, DatasetSpec, QuantConfig, QuantMode};
use crate::tensor::Matrix;
use crate::{Error, Result};

const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_READY: u8 = 3;
const TAG_STEPS: u8 = 4;
const TAG_STEP_RESULT: u8 = 5;
const TAG_EVALS: u8 = 6;
const TAG_EVAL_RESULT: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_ABORT: u8 = 9;
const TAG_HEARTBEAT: u8 = 10;
const TAG_HEARTBEAT_ACK: u8 = 11;
const TAG_REJOIN: u8 = 12;

/// Caps on repeated fields — far above any real run, low enough that a
/// desynced peer cannot make the decoder allocate absurdly.
const MAX_PARTS: usize = 1 << 20;
const MAX_WEIGHTS: usize = 1024;
const MAX_STRING: usize = 4096;
const MAX_BODY: usize = 1 << 31;

fn bad(msg: impl std::fmt::Display) -> Error {
    Error::Runtime(format!("dist protocol: {msg}"))
}

/// Everything a worker needs to reconstruct the leader's training
/// context from scratch: the dataset is *regenerated* (spec + seed), the
/// graph re-partitioned locally, and the agreement cross-checked via the
/// [`HaloOwnership`](crate::partition::HaloOwnership) fingerprint — no
/// subgraph bytes ever cross the wire.
#[derive(Debug, Clone)]
pub(crate) struct WorkerSetup {
    pub spec: DatasetSpec,
    pub dataset_seed: u64,
    /// The run seed: keys the per-`(epoch, partition)` step streams and
    /// the cache slot streams.
    pub seed: u64,
    pub quant: QuantConfig,
    pub arch: Arch,
    pub hidden_dim: usize,
    pub num_layers: usize,
    pub num_partitions: usize,
    pub halo_hops: usize,
    pub cache_bits: u32,
    pub allocation: AllocationConfig,
    /// The leader's halo ownership digest; a worker whose local
    /// partitioning disagrees must abort rather than train.
    pub ownership_fingerprint: u64,
    /// Mid-run rejoin context: the last realloc epoch and its
    /// epoch-start weights. A restarted worker re-solves all bit plans
    /// from these — bit-identically to what the surviving workers
    /// solved at that epoch — instead of starting from epoch 0 state.
    /// `None` at the start of a run (or under fixed allocation).
    pub plans_from: Option<(u64, Vec<Matrix>)>,
}

fn write_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    write_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn read_f64(r: &mut Reader<'_>) -> Result<f64> {
    Ok(f64::from_le_bytes(r.take(8)?.try_into().unwrap()))
}

fn read_str(r: &mut Reader<'_>) -> Result<String> {
    let len = r.u64()? as usize;
    if len > MAX_STRING {
        return Err(bad(format!("string length {len} exceeds {MAX_STRING}")));
    }
    String::from_utf8(r.take(len)?.to_vec()).map_err(|_| bad("string is not valid UTF-8"))
}

fn write_parts(buf: &mut Vec<u8>, parts: &[u64]) {
    write_u64(buf, parts.len() as u64);
    for &p in parts {
        write_u64(buf, p);
    }
}

fn read_parts(r: &mut Reader<'_>) -> Result<Vec<u64>> {
    let n = r.u64()? as usize;
    if n > MAX_PARTS {
        return Err(bad(format!("partition list length {n} exceeds {MAX_PARTS}")));
    }
    (0..n).map(|_| r.u64()).collect()
}

fn write_matrices(buf: &mut Vec<u8>, ms: &[Matrix]) {
    write_u32(buf, ms.len() as u32);
    for m in ms {
        write_matrix(buf, m);
    }
}

fn read_matrices(r: &mut Reader<'_>) -> Result<Vec<Matrix>> {
    let n = r.u32()? as usize;
    if n > MAX_WEIGHTS {
        return Err(bad(format!("matrix list length {n} exceeds {MAX_WEIGHTS}")));
    }
    (0..n).map(|_| r.matrix()).collect()
}

impl WorkerSetup {
    fn write(&self, buf: &mut Vec<u8>) {
        write_str(buf, &self.spec.name);
        write_u64(buf, self.spec.num_nodes as u64);
        write_u64(buf, self.spec.num_features as u64);
        write_u64(buf, self.spec.num_classes as u64);
        write_f64(buf, self.spec.mean_degree);
        write_f64(buf, self.spec.feature_snr);
        write_f64(buf, self.spec.homophily);
        write_u64(buf, self.dataset_seed);
        write_u64(buf, self.seed);
        let (mode, group_ratio) = match self.quant.mode {
            QuantMode::Fp32 => (0u8, 0u64),
            QuantMode::RowWise => (1, 0),
            QuantMode::BlockWise { group_ratio } => (2, group_ratio as u64),
            QuantMode::RowWiseVm => (3, 0),
        };
        buf.push(mode);
        write_u64(buf, group_ratio);
        write_u32(buf, self.quant.bits);
        write_u64(buf, self.quant.proj_ratio as u64);
        buf.push(match self.arch {
            Arch::Gcn => 0,
            Arch::GraphSage => 1,
        });
        write_u64(buf, self.hidden_dim as u64);
        write_u64(buf, self.num_layers as u64);
        write_u64(buf, self.num_partitions as u64);
        write_u64(buf, self.halo_hops as u64);
        write_u32(buf, self.cache_bits);
        buf.push(match self.allocation.strategy {
            AllocStrategy::Fixed => 0,
            AllocStrategy::Greedy => 1,
        });
        write_f64(buf, self.allocation.budget_bits);
        write_u64(buf, self.allocation.realloc_interval_epochs as u64);
        write_u32(buf, self.allocation.min_bits);
        write_u32(buf, self.allocation.max_bits);
        write_u64(buf, self.ownership_fingerprint);
        match &self.plans_from {
            None => buf.push(0),
            Some((epoch, weights)) => {
                buf.push(1);
                write_u64(buf, *epoch);
                write_matrices(buf, weights);
            }
        }
    }

    fn read(r: &mut Reader<'_>) -> Result<WorkerSetup> {
        let name = read_str(r)?;
        let spec = DatasetSpec {
            name,
            num_nodes: r.u64()? as usize,
            num_features: r.u64()? as usize,
            num_classes: r.u64()? as usize,
            mean_degree: read_f64(r)?,
            feature_snr: read_f64(r)?,
            homophily: read_f64(r)?,
        };
        let dataset_seed = r.u64()?;
        let seed = r.u64()?;
        let mode_tag = r.byte()?;
        let group_ratio = r.u64()? as usize;
        let mode = match mode_tag {
            0 => QuantMode::Fp32,
            1 => QuantMode::RowWise,
            2 => QuantMode::BlockWise { group_ratio },
            3 => QuantMode::RowWiseVm,
            other => return Err(bad(format!("bad quant mode tag {other}"))),
        };
        let quant = QuantConfig {
            mode,
            bits: r.u32()?,
            proj_ratio: r.u64()? as usize,
        };
        let arch = match r.byte()? {
            0 => Arch::Gcn,
            1 => Arch::GraphSage,
            other => return Err(bad(format!("bad arch byte {other}"))),
        };
        let hidden_dim = r.u64()? as usize;
        let num_layers = r.u64()? as usize;
        let num_partitions = r.u64()? as usize;
        let halo_hops = r.u64()? as usize;
        let cache_bits = r.u32()?;
        let strategy = match r.byte()? {
            0 => AllocStrategy::Fixed,
            1 => AllocStrategy::Greedy,
            other => return Err(bad(format!("bad allocation strategy byte {other}"))),
        };
        let allocation = AllocationConfig {
            strategy,
            budget_bits: read_f64(r)?,
            realloc_interval_epochs: r.u64()? as usize,
            min_bits: r.u32()?,
            max_bits: r.u32()?,
        };
        let ownership_fingerprint = r.u64()?;
        let plans_from = match r.byte()? {
            0 => None,
            1 => {
                let epoch = r.u64()?;
                let weights = read_matrices(r)?;
                Some((epoch, weights))
            }
            other => return Err(bad(format!("bad plans_from tag {other}"))),
        };
        Ok(WorkerSetup {
            spec,
            dataset_seed,
            seed,
            quant,
            arch,
            hidden_dim,
            num_layers,
            num_partitions,
            halo_hops,
            cache_bits,
            allocation,
            ownership_fingerprint,
            plans_from,
        })
    }
}

/// One protocol message. Partition indices travel as `u64` so the wire
/// layout is pointer-width-independent.
#[derive(Debug, Clone)]
pub(crate) enum Msg {
    /// Worker → leader, first message on connect.
    Hello { rank: u32 },
    /// Leader → worker: the full training context (boxed — it dwarfs the
    /// other variants).
    Setup(Box<WorkerSetup>),
    /// Worker → leader: local partitioning agrees with the leader's.
    Ready { fingerprint: u64 },
    /// Leader → worker: run these partitions' gradient steps at `epoch`
    /// from these weights; reply with one `StepResult` per partition in
    /// order.
    Steps {
        epoch: u64,
        parts: Vec<u64>,
        weights: Vec<Matrix>,
    },
    /// Worker → leader: one partition step's loss, peak stash bytes and
    /// f32 gradients.
    StepResult {
        part: u64,
        loss: f64,
        stash_bytes: u64,
        grads: Vec<Matrix>,
    },
    /// Leader → worker: forward these partitions at `epoch`'s
    /// post-update weights and reply with packed logits.
    Evals {
        epoch: u64,
        parts: Vec<u64>,
        weights: Vec<Matrix>,
    },
    /// Worker → leader: one partition's logits as a packed
    /// planned-tensor body (quantized codes + plan header — never f32).
    EvalResult { part: u64, body: Vec<u8> },
    /// Leader → worker: training is over, exit cleanly.
    Shutdown,
    /// Either direction: unrecoverable divergence; the run must stop.
    Abort { reason: String },
    /// Leader → worker: liveness probe. The nonce ties each ack to its
    /// probe so a late ack from a previous probe cannot satisfy a new
    /// one.
    Heartbeat { nonce: u64 },
    /// Worker → leader: echo of a probe's nonce.
    HeartbeatAck { nonce: u64 },
    /// Worker → leader, first message of a *restarted* worker: resume
    /// `rank`'s seat mid-run (the leader replies with a fresh `Setup`
    /// carrying `plans_from`).
    Rejoin { rank: u32 },
}

impl Msg {
    /// Variant name for protocol diagnostics.
    pub(crate) fn kind(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::Setup(_) => "Setup",
            Msg::Ready { .. } => "Ready",
            Msg::Steps { .. } => "Steps",
            Msg::StepResult { .. } => "StepResult",
            Msg::Evals { .. } => "Evals",
            Msg::EvalResult { .. } => "EvalResult",
            Msg::Shutdown => "Shutdown",
            Msg::Abort { .. } => "Abort",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::HeartbeatAck { .. } => "HeartbeatAck",
            Msg::Rejoin { .. } => "Rejoin",
        }
    }

    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Msg::Hello { rank } => {
                buf.push(TAG_HELLO);
                write_u32(&mut buf, *rank);
            }
            Msg::Setup(s) => {
                buf.push(TAG_SETUP);
                s.write(&mut buf);
            }
            Msg::Ready { fingerprint } => {
                buf.push(TAG_READY);
                write_u64(&mut buf, *fingerprint);
            }
            Msg::Steps {
                epoch,
                parts,
                weights,
            } => {
                buf.push(TAG_STEPS);
                write_u64(&mut buf, *epoch);
                write_parts(&mut buf, parts);
                write_matrices(&mut buf, weights);
            }
            Msg::StepResult {
                part,
                loss,
                stash_bytes,
                grads,
            } => {
                buf.push(TAG_STEP_RESULT);
                write_u64(&mut buf, *part);
                write_f64(&mut buf, *loss);
                write_u64(&mut buf, *stash_bytes);
                write_matrices(&mut buf, grads);
            }
            Msg::Evals {
                epoch,
                parts,
                weights,
            } => {
                buf.push(TAG_EVALS);
                write_u64(&mut buf, *epoch);
                write_parts(&mut buf, parts);
                write_matrices(&mut buf, weights);
            }
            Msg::EvalResult { part, body } => {
                buf.push(TAG_EVAL_RESULT);
                write_u64(&mut buf, *part);
                write_u64(&mut buf, body.len() as u64);
                buf.extend_from_slice(body);
            }
            Msg::Shutdown => buf.push(TAG_SHUTDOWN),
            Msg::Abort { reason } => {
                buf.push(TAG_ABORT);
                write_str(&mut buf, reason);
            }
            Msg::Heartbeat { nonce } => {
                buf.push(TAG_HEARTBEAT);
                write_u64(&mut buf, *nonce);
            }
            Msg::HeartbeatAck { nonce } => {
                buf.push(TAG_HEARTBEAT_ACK);
                write_u64(&mut buf, *nonce);
            }
            Msg::Rejoin { rank } => {
                buf.push(TAG_REJOIN);
                write_u32(&mut buf, *rank);
            }
        }
        buf
    }

    pub(crate) fn decode(payload: &[u8]) -> Result<Msg> {
        let mut r = Reader {
            cur: payload,
            what: "dist message",
        };
        // Reader truncation errors are Artifact("dist message truncated");
        // requalify them as protocol errors — on a socket they mean a
        // desynced peer, not a damaged file.
        let msg = Self::decode_body(&mut r).map_err(|e| match e {
            Error::Artifact(m) => bad(m),
            other => other,
        })?;
        if !r.cur.is_empty() {
            return Err(bad(format!(
                "{} bytes trailing a {} message",
                r.cur.len(),
                msg.kind()
            )));
        }
        Ok(msg)
    }

    fn decode_body(r: &mut Reader<'_>) -> Result<Msg> {
        Ok(match r.byte()? {
            TAG_HELLO => Msg::Hello { rank: r.u32()? },
            TAG_SETUP => Msg::Setup(Box::new(WorkerSetup::read(r)?)),
            TAG_READY => Msg::Ready {
                fingerprint: r.u64()?,
            },
            TAG_STEPS => Msg::Steps {
                epoch: r.u64()?,
                parts: read_parts(r)?,
                weights: read_matrices(r)?,
            },
            TAG_STEP_RESULT => Msg::StepResult {
                part: r.u64()?,
                loss: read_f64(r)?,
                stash_bytes: r.u64()?,
                grads: read_matrices(r)?,
            },
            TAG_EVALS => Msg::Evals {
                epoch: r.u64()?,
                parts: read_parts(r)?,
                weights: read_matrices(r)?,
            },
            TAG_EVAL_RESULT => {
                let part = r.u64()?;
                let len = r.u64()? as usize;
                if len > MAX_BODY {
                    return Err(bad(format!("eval body length {len} exceeds {MAX_BODY}")));
                }
                Msg::EvalResult {
                    part,
                    body: r.take(len)?.to_vec(),
                }
            }
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_ABORT => Msg::Abort {
                reason: read_str(r)?,
            },
            TAG_HEARTBEAT => Msg::Heartbeat { nonce: r.u64()? },
            TAG_HEARTBEAT_ACK => Msg::HeartbeatAck { nonce: r.u64()? },
            TAG_REJOIN => Msg::Rejoin { rank: r.u32()? },
            other => return Err(bad(format!("unknown message tag {other}"))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> WorkerSetup {
        WorkerSetup {
            spec: DatasetSpec::tiny(),
            dataset_seed: 7,
            seed: 42,
            quant: QuantConfig::int2_blockwise(8),
            arch: Arch::GraphSage,
            hidden_dim: 32,
            num_layers: 3,
            num_partitions: 4,
            halo_hops: 1,
            cache_bits: 2,
            allocation: AllocationConfig {
                strategy: AllocStrategy::Greedy,
                budget_bits: 2.5,
                realloc_interval_epochs: 5,
                min_bits: 1,
                max_bits: 8,
            },
            ownership_fingerprint: 0xdead_beef_cafe_f00d,
            plans_from: None,
        }
    }

    fn roundtrip(msg: &Msg) -> Msg {
        Msg::decode(&msg.encode()).unwrap()
    }

    #[test]
    fn all_variants_round_trip() {
        let m = Matrix::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        match roundtrip(&Msg::Hello { rank: 3 }) {
            Msg::Hello { rank } => assert_eq!(rank, 3),
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::Setup(Box::new(setup()))) {
            Msg::Setup(s) => {
                let want = setup();
                assert_eq!(s.spec.name, want.spec.name);
                assert_eq!(s.spec.num_nodes, want.spec.num_nodes);
                assert_eq!(s.spec.homophily, want.spec.homophily);
                assert_eq!(s.quant, want.quant);
                assert_eq!(s.arch, want.arch);
                assert_eq!(s.num_partitions, 4);
                assert_eq!(s.cache_bits, 2);
                assert_eq!(s.allocation.strategy, AllocStrategy::Greedy);
                assert_eq!(s.allocation.budget_bits, 2.5);
                assert_eq!(s.ownership_fingerprint, want.ownership_fingerprint);
            }
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::Steps {
            epoch: 9,
            parts: vec![0, 2],
            weights: vec![m.clone()],
        }) {
            Msg::Steps {
                epoch,
                parts,
                weights,
            } => {
                assert_eq!(epoch, 9);
                assert_eq!(parts, vec![0, 2]);
                assert_eq!(weights, vec![m.clone()]);
            }
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::StepResult {
            part: 2,
            loss: 0.5,
            stash_bytes: 128,
            grads: vec![m.clone()],
        }) {
            Msg::StepResult {
                part,
                loss,
                stash_bytes,
                grads,
            } => {
                assert_eq!((part, loss, stash_bytes), (2, 0.5, 128));
                assert_eq!(grads, vec![m]);
            }
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::EvalResult {
            part: 1,
            body: vec![1, 2, 3],
        }) {
            Msg::EvalResult { part, body } => {
                assert_eq!(part, 1);
                assert_eq!(body, vec![1, 2, 3]);
            }
            other => panic!("{}", other.kind()),
        }
        assert!(matches!(roundtrip(&Msg::Shutdown), Msg::Shutdown));
        match roundtrip(&Msg::Abort {
            reason: "mismatch".into(),
        }) {
            Msg::Abort { reason } => assert_eq!(reason, "mismatch"),
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::Heartbeat { nonce: 0xfeed }) {
            Msg::Heartbeat { nonce } => assert_eq!(nonce, 0xfeed),
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::HeartbeatAck { nonce: 0xfeed }) {
            Msg::HeartbeatAck { nonce } => assert_eq!(nonce, 0xfeed),
            other => panic!("{}", other.kind()),
        }
        match roundtrip(&Msg::Rejoin { rank: 2 }) {
            Msg::Rejoin { rank } => assert_eq!(rank, 2),
            other => panic!("{}", other.kind()),
        }
    }

    #[test]
    fn setup_plans_from_round_trips() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f32 * 0.5);
        let mut s = setup();
        s.plans_from = Some((12, vec![m.clone(), m.clone()]));
        match roundtrip(&Msg::Setup(Box::new(s))) {
            Msg::Setup(got) => {
                let (epoch, weights) = got.plans_from.expect("plans_from lost on the wire");
                assert_eq!(epoch, 12);
                assert_eq!(weights, vec![m.clone(), m]);
            }
            other => panic!("{}", other.kind()),
        }
    }

    #[test]
    fn malformed_messages_are_named_protocol_errors() {
        // Unknown tag.
        let msg = Msg::decode(&[0xEE]).unwrap_err().to_string();
        assert!(msg.contains("dist protocol"), "{msg}");
        assert!(msg.contains("unknown message tag"), "{msg}");
        // Truncated body requalifies as a protocol error, not artifact.
        let mut bytes = Msg::Hello { rank: 1 }.encode();
        bytes.truncate(2);
        let msg = Msg::decode(&bytes).unwrap_err().to_string();
        assert!(msg.contains("dist protocol"), "{msg}");
        assert!(msg.contains("truncated"), "{msg}");
        // Trailing bytes.
        let mut bytes = Msg::Shutdown.encode();
        bytes.push(0);
        let msg = Msg::decode(&bytes).unwrap_err().to_string();
        assert!(msg.contains("trailing"), "{msg}");
        // Empty payload.
        assert!(Msg::decode(&[]).is_err());
    }
}
