//! AOT training coordinator: drives the JAX-lowered training-step
//! executables (Layer 2/1) from Rust via the PJRT runtime.
//!
//! Contract with `python/compile/aot.py` (see the manifest):
//!
//! * `train_step_{dataset}_{slug}` — inputs, in order:
//!   `features (N,F)`, `adj (N,N)` (dense Â), `onehot (N,C)`,
//!   `train_mask (N,1)`, `w0 (F,H)`, `w1 (H,H)`, `w2 (H,C)`,
//!   `m0,m1,m2`, `v0,v1,v2` (Adam moments, same shapes as weights),
//!   `t (1,1)` (Adam step counter), `key (1,2)` (PRNG key as f32 ints);
//!   outputs: updated `w*, m*, v*` then `loss (1,1)`.
//! * `eval_{dataset}` — inputs `features, adj, w0, w1, w2`;
//!   output `logits (N,C)`.
//!
//! The static tensors (features, Â, one-hot labels, mask) are converted
//! once at construction; only weights/opt-state/key change per step.

use crate::graph::Dataset;
use crate::metrics::{masked_accuracy, TrainCurve};
use crate::rngs::Pcg64;
use crate::runtime::Runtime;
use crate::tensor::Matrix;
use crate::util::timer::LapTimer;
use crate::{Error, Result};

/// Outcome of an AOT-driven training run.
#[derive(Debug, Clone)]
pub struct AotTrainOutcome {
    pub curve: TrainCurve,
    pub test_accuracy: f64,
    pub best_val_loss: f64,
    pub epochs_per_sec: f64,
    pub final_train_loss: f64,
}

/// Drives AOT train-step/eval artifacts for one dataset.
pub struct AotCoordinator<'rt> {
    runtime: &'rt mut Runtime,
    dataset_key: String,
    // Static inputs.
    features: Matrix,
    adj_dense: Matrix,
    onehot: Matrix,
    train_mask: Matrix,
    // Model + optimizer state (owned by rust between steps).
    weights: Vec<Matrix>,
    ms: Vec<Matrix>,
    vs: Vec<Matrix>,
    t: f32,
    rng: Pcg64,
}

impl<'rt> AotCoordinator<'rt> {
    /// Prepare static tensors and initialize weights to match the
    /// `train_step_{dataset_key}_{slug}` artifact shapes.
    pub fn new(
        runtime: &'rt mut Runtime,
        dataset_key: &str,
        slug: &str,
        dataset: &Dataset,
        seed: u64,
    ) -> Result<Self> {
        dataset.validate()?;
        let name = format!("train_step_{dataset_key}_{slug}");
        let entry = runtime.load(&name)?.entry.clone();
        // Weights are inputs 4..7 by the contract.
        if entry.inputs.len() != 15 {
            return Err(Error::Artifact(format!(
                "'{name}' should have 15 inputs, has {}",
                entry.inputs.len()
            )));
        }
        let n = dataset.num_nodes();
        let c = dataset.num_classes;
        let mut rng = Pcg64::new(seed ^ 0xa07);
        let weights: Vec<Matrix> = entry.inputs[4..7]
            .iter()
            .map(|spec| crate::linalg::glorot_uniform(spec.rows, spec.cols, &mut rng))
            .collect();
        let zeros_like =
            |specs: &[crate::runtime::TensorSpec]| -> Vec<Matrix> {
                specs.iter().map(|s| Matrix::zeros(s.rows, s.cols)).collect()
            };
        let ms = zeros_like(&entry.inputs[7..10]);
        let vs = zeros_like(&entry.inputs[10..13]);

        let mut onehot = Matrix::zeros(n, c);
        for (i, &l) in dataset.labels.iter().enumerate() {
            onehot.set(i, l as usize, 1.0);
        }
        let train_mask = Matrix::from_fn(n, 1, |i, _| {
            if dataset.train_mask[i] {
                1.0
            } else {
                0.0
            }
        });

        Ok(AotCoordinator {
            runtime,
            dataset_key: dataset_key.to_string(),
            features: dataset.features.clone(),
            adj_dense: dataset.adj.to_dense(),
            onehot,
            train_mask,
            weights,
            ms,
            vs,
            t: 0.0,
            rng,
        })
    }

    /// Execute one training step; returns the loss.
    pub fn step(&mut self, slug: &str) -> Result<f64> {
        self.t += 1.0;
        let t = Matrix::from_vec(1, 1, vec![self.t])?;
        let key = Matrix::from_vec(
            1,
            2,
            vec![
                (self.rng.next_u64() & 0xff_ffff) as f32,
                (self.rng.next_u64() & 0xff_ffff) as f32,
            ],
        )?;
        let name = format!("train_step_{}_{slug}", self.dataset_key);
        let inputs: Vec<&Matrix> = vec![
            &self.features,
            &self.adj_dense,
            &self.onehot,
            &self.train_mask,
            &self.weights[0],
            &self.weights[1],
            &self.weights[2],
            &self.ms[0],
            &self.ms[1],
            &self.ms[2],
            &self.vs[0],
            &self.vs[1],
            &self.vs[2],
            &t,
            &key,
        ];
        let mut out = self.runtime.execute(&name, &inputs)?;
        if out.len() != 10 {
            return Err(Error::Runtime(format!(
                "train step returned {} outputs, expected 10",
                out.len()
            )));
        }
        let loss = out.pop().unwrap().get(0, 0) as f64;
        // Outputs: w0,w1,w2, m0..2, v0..2 in order.
        let mut it = out.into_iter();
        for w in self.weights.iter_mut() {
            *w = it.next().unwrap();
        }
        for m in self.ms.iter_mut() {
            *m = it.next().unwrap();
        }
        for v in self.vs.iter_mut() {
            *v = it.next().unwrap();
        }
        Ok(loss)
    }

    /// Run the eval artifact with the current weights.
    pub fn logits(&mut self) -> Result<Matrix> {
        let name = format!("eval_{}", self.dataset_key);
        let inputs: Vec<&Matrix> = vec![
            &self.features,
            &self.adj_dense,
            &self.weights[0],
            &self.weights[1],
            &self.weights[2],
        ];
        let mut out = self.runtime.execute(&name, &inputs)?;
        out.pop()
            .ok_or_else(|| Error::Runtime("eval returned no outputs".into()))
    }

    /// Full training loop: `epochs` steps with periodic evaluation;
    /// reports test accuracy at the best-validation epoch.
    pub fn train(
        &mut self,
        slug: &str,
        dataset: &Dataset,
        epochs: usize,
        eval_every: usize,
    ) -> Result<AotTrainOutcome> {
        let mut curve = TrainCurve::default();
        let mut timer = LapTimer::new();
        let mut best_val_loss = f64::INFINITY;
        let mut test_at_best = 0.0;
        let mut final_train_loss = f64::NAN;
        for epoch in 0..epochs {
            let loss = timer.lap(|| self.step(slug))?;
            final_train_loss = loss;
            if epoch % eval_every.max(1) == 0 || epoch + 1 == epochs {
                let logits = self.logits()?;
                let (val_loss, _) = crate::linalg::softmax_cross_entropy(
                    &logits,
                    &dataset.labels,
                    &dataset.val_mask,
                )?;
                let val_acc =
                    masked_accuracy(&logits, &dataset.labels, &dataset.val_mask);
                curve.push(epoch, loss, val_loss, val_acc);
                if val_loss < best_val_loss {
                    best_val_loss = val_loss;
                    test_at_best =
                        masked_accuracy(&logits, &dataset.labels, &dataset.test_mask);
                }
            }
        }
        Ok(AotTrainOutcome {
            curve,
            test_accuracy: test_at_best,
            best_val_loss,
            epochs_per_sec: timer.rate_per_sec(),
            final_train_loss,
        })
    }

    pub fn weights(&self) -> &[Matrix] {
        &self.weights
    }
}
