//! Training coordinator (Layer 3).
//!
//! Owns run plans (dataset × quant-config × seeds), drives either the
//! **native pipeline** (pure Rust, used for the Table 1 sweep) or the
//! **AOT runtime path** (PJRT-executed JAX training steps, proving the
//! three-layer composition), aggregates metrics, and produces the
//! Table 1 rows.
//!
//! Each native run builds its quantization engine from the experiment's
//! `[parallelism]` config (see
//! [`ParallelismConfig`](crate::config::ParallelismConfig)); the engine's
//! per-block RNG streams guarantee that a sweep's numbers are identical
//! whatever thread count each cell ran with.
//!
//! The [`dist`] submodule scales the partitioned trainer across
//! **worker processes**: a leader drives workers over localhost TCP,
//! halo/eval activations cross process boundaries as packed quantized
//! codes, and the run stays bit-identical to the single-process loop
//! at any worker count.

mod aot;
pub mod dist;

pub use aot::{AotCoordinator, AotTrainOutcome};

use crate::config::{ExperimentConfig, QuantConfig, TrainConfig};
use crate::graph::Dataset;
use crate::memory::MemoryModel;
use crate::metrics::{Aggregate, RunSummary};
use crate::pipeline::{train, TrainResult};
use crate::Result;

/// All results of one (dataset × config) cell.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub summary: RunSummary,
    pub results: Vec<TrainResult>,
}

/// Run one experiment cell over all its seeds on the native pipeline.
pub fn run_native(cfg: &ExperimentConfig) -> Result<RunOutcome> {
    cfg.validate()?;
    let dataset = cfg.dataset.generate(cfg.dataset_seed);
    run_native_on(&dataset, &cfg.quant, &cfg.train)
}

/// Like [`run_native`] but on a pre-generated dataset (so a sweep shares
/// one graph across configs, as the paper does).
pub fn run_native_on(
    dataset: &Dataset,
    quant: &QuantConfig,
    train_cfg: &TrainConfig,
) -> Result<RunOutcome> {
    // This is a public entry point callable without `cfg.validate()`
    // (unlike `run_native`), and the mean rate below divides by the seed
    // count — an empty list would yield NaN `epochs_per_sec` and a
    // zero-count accuracy aggregate instead of an error.
    if train_cfg.seeds.is_empty() {
        return Err(crate::Error::Config("train.seeds must be non-empty".into()));
    }
    let mut acc = Aggregate::new();
    let mut rate = 0.0;
    let mut results = Vec::with_capacity(train_cfg.seeds.len());
    for &seed in &train_cfg.seeds {
        let r = train(dataset, quant, train_cfg, seed)?;
        acc.add(r.test_accuracy * 100.0);
        rate += r.epochs_per_sec;
        results.push(r);
    }
    rate /= train_cfg.seeds.len() as f64;

    let mem = MemoryModel::for_arch(
        train_cfg.arch,
        dataset.num_nodes(),
        dataset.num_features(),
        train_cfg.hidden_dim,
        train_cfg.num_layers,
    );
    let summary = RunSummary {
        dataset: dataset.name.clone(),
        config_label: quant.label(),
        accuracy: acc,
        epochs_per_sec: rate,
        memory_mb: mem.total_mb(quant)?,
    };
    Ok(RunOutcome { summary, results })
}

/// The Table 1 config column: FP32, EXACT, the G/R sweep, and VM.
pub fn table1_configs(group_ratios: &[usize]) -> Vec<QuantConfig> {
    let mut configs = vec![QuantConfig::fp32(), QuantConfig::int2_exact()];
    configs.extend(
        group_ratios
            .iter()
            .map(|&g| QuantConfig::int2_blockwise(g)),
    );
    configs.push(QuantConfig::int2_vm());
    configs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;

    #[test]
    fn run_native_aggregates_seeds() {
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::tiny(),
            quant: QuantConfig::int2_blockwise(8),
            train: TrainConfig {
                hidden_dim: 32,
                epochs: 12,
                seeds: vec![0, 1],
                eval_every: 4,
                ..TrainConfig::default()
            },
            dataset_seed: 3,
        };
        let out = run_native(&cfg).unwrap();
        assert_eq!(out.results.len(), 2);
        assert_eq!(out.summary.accuracy.count(), 2);
        assert!(out.summary.memory_mb > 0.0);
        assert!(out.summary.epochs_per_sec > 0.0);
        assert_eq!(out.summary.dataset, "tiny");
    }

    #[test]
    fn run_native_on_rejects_empty_seeds() {
        // Regression: an empty seed list used to divide by zero into a
        // NaN epochs_per_sec and an empty aggregate; it must be a
        // key-pathed config error.
        let ds = DatasetSpec::tiny().generate(1);
        let cfg = TrainConfig {
            seeds: vec![],
            ..TrainConfig::default()
        };
        let err = run_native_on(&ds, &QuantConfig::int2_blockwise(8), &cfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("train.seeds"), "unexpected message: {msg}");
    }

    #[test]
    fn run_native_results_invariant_to_parallelism() {
        // The coordinator must report identical numbers for a cell no
        // matter how the quantization engine is threaded.
        let mk = |parallelism| ExperimentConfig {
            dataset: DatasetSpec::tiny(),
            quant: QuantConfig::int2_blockwise(4),
            train: TrainConfig {
                hidden_dim: 32,
                epochs: 6,
                seeds: vec![0],
                eval_every: 3,
                parallelism,
                ..TrainConfig::default()
            },
            dataset_seed: 3,
        };
        use crate::config::ParallelismConfig;
        let serial = run_native(&mk(ParallelismConfig::serial())).unwrap();
        let parallel = run_native(&mk(ParallelismConfig {
            threads: 8,
            min_blocks_per_shard: 1,
            ..ParallelismConfig::default()
        }))
        .unwrap();
        assert_eq!(
            serial.results[0].final_train_loss,
            parallel.results[0].final_train_loss
        );
        assert_eq!(serial.summary.memory_mb, parallel.summary.memory_mb);
    }

    #[test]
    fn table1_configs_cover_paper_rows() {
        let c = table1_configs(&[2, 4, 8, 16, 32, 64]);
        assert_eq!(c.len(), 9); // fp32 + exact + 6 ratios + vm
        assert_eq!(c[0], QuantConfig::fp32());
        assert_eq!(c[8], QuantConfig::int2_vm());
    }
}
