//! Property tests for the adaptive bit-allocation subsystem (ISSUE 2):
//! every plan a [`BitAllocator`] produces respects its width bounds and
//! average budget, planned quantization round-trips (bit-exactly equal
//! to the fixed-width engine at a constant width, lossless at 8 bits on
//! grid-aligned inputs), and the adaptive plan beats fixed INT2 at an
//! equal average budget on block-heterogeneous activations.

use iexact::alloc::{BitAllocator, BitPlan, BlockStats};
use iexact::engine::QuantEngine;
use iexact::quant::BinSpec;
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;
use iexact::util::prop;

fn hetero_stats(nb: usize, group_len: usize, seed: u64) -> BlockStats {
    let mut rng = Pcg64::new(seed);
    BlockStats {
        ranges: (0..nb)
            .map(|_| (rng.next_normal() * 1.2).exp() as f32)
            .collect(),
        group_len,
        n_scalars: nb * group_len,
        model_d: 32,
    }
}

#[test]
fn every_plan_respects_bounds_and_budget() {
    // Random (budget, block-count) pairs: the plan's widths stay within
    // [min_bits, max_bits], the scalar-average width stays within the
    // budget, and the solver leaves less than one block's largest
    // upgrade unspent (unless every block is already at max_bits).
    prop::check(
        "plan bounds and budget",
        60,
        prop::pair(prop::f64_range(1.0, 8.0), prop::usize_range(1, 96)),
        |&(budget, nb)| {
            let stats = hetero_stats(nb, 16, nb as u64 + 1);
            let plan = BitAllocator::new(budget, 1, 8).unwrap().allocate(&stats).unwrap();
            let widths_ok = plan.bits().iter().all(|&b| [1u8, 2, 4, 8].contains(&b));
            let avg = plan.avg_bits();
            let under_budget = avg <= budget + 1e-9;
            let saturated = plan.bits().iter().all(|&b| b == 8);
            // Largest single upgrade is 4→8: 4 bits × one block.
            let nearly_exhausted = saturated || budget - avg <= 4.0 / nb as f64 + 1e-9;
            widths_ok && under_budget && nearly_exhausted
        },
    );
}

#[test]
fn constrained_ladders_respect_bounds() {
    prop::check(
        "constrained ladder bounds",
        40,
        prop::pair(prop::f64_range(2.0, 4.0), prop::usize_range(1, 48)),
        |&(budget, nb)| {
            let stats = hetero_stats(nb, 8, nb as u64 + 101);
            let plan = BitAllocator::new(budget, 2, 4).unwrap().allocate(&stats).unwrap();
            plan.bits().iter().all(|&b| b == 2 || b == 4) && plan.avg_bits() <= budget + 1e-9
        },
    );
}

#[test]
fn planned_quantization_roundtrips_within_per_block_width() {
    // Under any random plan, |ĥ − h| ≤ range_g / (2^{b_g} − 1).
    prop::check(
        "planned roundtrip error bound",
        25,
        prop::usize_range(1, 40),
        |&nb| {
            let g = 24;
            let mut rng = Pcg64::new(nb as u64 + 7);
            let h = Matrix::from_fn(nb, g, |_, _| rng.next_f32() * 6.0 - 3.0);
            let bits: Vec<u8> = (0..nb)
                .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
                .collect();
            let plan = BitPlan::new(bits, g).unwrap();
            let pt = QuantEngine::auto()
                .quantize_planned_seeded(&h, &plan, nb as u64)
                .unwrap();
            let d = pt.dequantize().unwrap();
            h.as_slice().iter().zip(d.as_slice()).enumerate().all(
                |(idx, (&orig, &deq))| {
                    let blk = idx / g;
                    let b = ((1u32 << plan.bit(blk)) - 1) as f32;
                    (orig - deq).abs() <= pt.ranges[blk] / b * 1.0001
                },
            )
        },
    );
}

#[test]
fn eight_bit_plan_roundtrips_grid_values_losslessly() {
    // Values sitting exactly on the 8-bit grid (0..=255 in each block)
    // reconstruct bit-exactly: SR on a boundary never moves, and the
    // dequant LUT maps code k back to z + k·(r/255) = the original.
    let rows = 16;
    let cols = 64; // 1024 scalars, G = 256 -> 4 blocks, each hits 0 and 255
    let h = Matrix::from_fn(rows, cols, |r, c| ((r * cols + c) % 256) as f32);
    let plan = BitPlan::uniform(8, (rows * cols) / 256, 256).unwrap();
    for threads in [1usize, 4] {
        let pt = QuantEngine::with_threads(threads)
            .quantize_planned_seeded(&h, &plan, 99)
            .unwrap();
        let d = pt.dequantize().unwrap();
        assert_eq!(d.as_slice(), h.as_slice(), "threads={threads}");
    }
}

#[test]
fn uniform_plans_match_fixed_width_engine_bit_exactly() {
    // The planned path at a constant width is the fixed-width path:
    // same packed bytes, same metadata, same dequantization.
    let mut rng = Pcg64::new(12);
    let h = Matrix::from_fn(48, 32, |_, _| rng.next_f32() * 2.0 - 1.0);
    for bits in [2u32, 4, 8] {
        let fixed = QuantEngine::serial()
            .quantize_seeded(&h, 32, bits, &BinSpec::Uniform, 555)
            .unwrap();
        let plan = BitPlan::uniform(bits, 48, 32).unwrap();
        let planned = QuantEngine::serial()
            .quantize_planned_seeded(&h, &plan, 555)
            .unwrap();
        assert_eq!(planned.packed, fixed.packed, "bits={bits}");
        assert_eq!(planned.zeros, fixed.zeros, "bits={bits}");
        assert_eq!(planned.ranges, fixed.ranges, "bits={bits}");
        assert_eq!(
            planned.dequantize().unwrap().as_slice(),
            fixed.dequantize().unwrap().as_slice(),
            "bits={bits}"
        );
    }
}

#[test]
fn adaptive_beats_fixed_int2_at_equal_budget() {
    // ISSUE 2 acceptance: on block-heterogeneous activations the greedy
    // plan at an average 2-bit budget realizes lower quantize→dequantize
    // MSE than fixed INT2, at no more stored bytes.
    let nb = 512;
    let g = 64;
    let mut rng = Pcg64::new(21);
    let mut data = Vec::with_capacity(nb * g);
    for _ in 0..nb {
        let scale = (rng.next_normal() * 1.2).exp() as f32;
        for _ in 0..g {
            data.push(rng.next_f32() * scale);
        }
    }
    let h = Matrix::from_vec(nb, g, data).unwrap();
    let stats = BlockStats::measure(&h, g).unwrap();
    let plan = BitAllocator::new(2.0, 1, 8).unwrap().allocate(&stats).unwrap();
    assert!(plan.avg_bits() <= 2.0 + 1e-9);

    let engine = QuantEngine::auto();
    let mse = |a: &Matrix, b: &Matrix| -> f64 {
        a.as_slice()
            .iter()
            .zip(b.as_slice())
            .map(|(&x, &y)| (x as f64 - y as f64) * (x as f64 - y as f64))
            .sum::<f64>()
            / a.len() as f64
    };
    let mut err_fixed = 0.0;
    let mut err_adaptive = 0.0;
    let mut bytes_fixed = 0;
    let mut bytes_adaptive = 0;
    for seed in 0..4u64 {
        let ct = engine
            .quantize_seeded(&h, g, 2, &BinSpec::Uniform, seed)
            .unwrap();
        bytes_fixed = ct.nbytes();
        err_fixed += mse(&h, &engine.dequantize(&ct).unwrap());
        let pt = engine.quantize_planned_seeded(&h, &plan, seed).unwrap();
        bytes_adaptive = pt.nbytes();
        err_adaptive += mse(&h, &engine.dequantize_planned(&pt).unwrap());
    }
    assert!(
        bytes_adaptive <= bytes_fixed,
        "adaptive {bytes_adaptive} bytes vs fixed {bytes_fixed}"
    );
    assert!(
        err_adaptive < err_fixed,
        "adaptive MSE {err_adaptive} vs fixed {err_fixed}"
    );
}

#[test]
fn allocation_is_deterministic() {
    let stats = hetero_stats(64, 16, 3);
    let a = BitAllocator::new(2.5, 1, 8).unwrap().allocate(&stats).unwrap();
    let b = BitAllocator::new(2.5, 1, 8).unwrap().allocate(&stats).unwrap();
    assert_eq!(a, b);
}
