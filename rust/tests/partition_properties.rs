//! Property tests for the graph partitioner (ISSUE 3): core-partition
//! exactness, halo correctness against an independent reference
//! implementation, and determinism — of the partitioner itself (pure
//! function, no threads) and of partitioned *training* across engine
//! thread counts.

use iexact::config::{DatasetSpec, ParallelismConfig, PartitionConfig, QuantConfig, TrainConfig};
use iexact::graph::Dataset;
use iexact::partition::{partition_dataset, PartitionSet};
use iexact::pipeline::train_partitioned;
use std::collections::HashSet;

fn dataset(seed: u64) -> Dataset {
    DatasetSpec::tiny().generate(seed)
}

/// Independent reference for the `h`-hop boundary neighborhood: plain
/// set-based BFS from the core over the parent adjacency.
fn reference_halo(ds: &Dataset, core: &[usize], hops: usize) -> Vec<usize> {
    let core_set: HashSet<usize> = core.iter().copied().collect();
    let mut reached: HashSet<usize> = core_set.clone();
    let mut frontier: Vec<usize> = core.to_vec();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &u in &frontier {
            for &v in ds.adj.row(u).0 {
                if v != u && !reached.contains(&v) {
                    reached.insert(v);
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    let mut halo: Vec<usize> = reached.difference(&core_set).copied().collect();
    halo.sort_unstable();
    halo
}

#[test]
fn every_node_in_exactly_one_core() {
    for seed in [1u64, 2, 3] {
        let ds = dataset(seed);
        for k in [2usize, 3, 4, 8, 13] {
            let ps = partition_dataset(&ds, k, 0).unwrap();
            let mut count = vec![0usize; ds.num_nodes()];
            for p in &ps.parts {
                for &u in &p.core {
                    count[u] += 1;
                }
            }
            for (u, &c) in count.iter().enumerate() {
                assert_eq!(c, 1, "seed {seed} k {k}: node {u} in {c} cores");
            }
        }
    }
}

#[test]
fn halo_equals_reference_h_hop_boundary() {
    let ds = dataset(4);
    for hops in [0usize, 1, 2, 3] {
        let ps = partition_dataset(&ds, 4, hops).unwrap();
        for (i, p) in ps.parts.iter().enumerate() {
            let expected = reference_halo(&ds, &p.core, hops);
            assert_eq!(
                p.halo, expected,
                "partition {i} at {hops} hops: halo does not match the true boundary"
            );
        }
    }
}

#[test]
fn node_map_merges_core_and_halo_and_masks_are_core_pure() {
    let ds = dataset(5);
    let ps = partition_dataset(&ds, 3, 2).unwrap();
    for p in &ps.parts {
        let mut expected: Vec<usize> = p.core.iter().chain(&p.halo).copied().collect();
        expected.sort_unstable();
        assert_eq!(p.node_map, expected);
        assert_eq!(p.core_mask.len(), p.node_map.len());
        for (local, &parent) in p.node_map.iter().enumerate() {
            let is_core = p.core.binary_search(&parent).is_ok();
            assert_eq!(p.core_mask[local], is_core);
            if is_core {
                // Core nodes keep their parent split membership.
                assert_eq!(p.data.train_mask[local], ds.train_mask[parent]);
                assert_eq!(p.data.val_mask[local], ds.val_mask[parent]);
                assert_eq!(p.data.test_mask[local], ds.test_mask[parent]);
            } else {
                assert!(
                    !p.data.train_mask[local]
                        && !p.data.val_mask[local]
                        && !p.data.test_mask[local],
                    "halo node {parent} kept a split"
                );
            }
            // Features and labels line up with the parent.
            assert_eq!(p.data.labels[local], ds.labels[parent]);
            assert_eq!(p.data.features.row(local), ds.features.row(parent));
        }
    }
}

fn fingerprint(ps: &PartitionSet) -> Vec<(Vec<usize>, Vec<usize>)> {
    ps.parts
        .iter()
        .map(|p| (p.core.clone(), p.halo.clone()))
        .collect()
}

#[test]
fn partitioning_is_deterministic() {
    let ds = dataset(6);
    let a = partition_dataset(&ds, 4, 1).unwrap();
    for _ in 0..3 {
        let b = partition_dataset(&ds, 4, 1).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_eq!(a.cut_edges, b.cut_edges);
    }
    // Regenerating the dataset (same seed) gives the same partitioning.
    let ds2 = dataset(6);
    let c = partition_dataset(&ds2, 4, 1).unwrap();
    assert_eq!(fingerprint(&a), fingerprint(&c));
}

#[test]
fn partitioned_training_is_identical_across_thread_counts() {
    // The partitioner draws no randomness and spawns no threads; the
    // trainer's engine threading is a pure speed knob. Together:
    // partitioned training at 1 vs 8 workers must agree bit-for-bit.
    let ds = dataset(7);
    let q = QuantConfig::int2_blockwise(4);
    let mut serial = TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 6,
        lr: 0.02,
        eval_every: 3,
        seeds: vec![0],
        ..TrainConfig::default()
    };
    serial.parallelism = ParallelismConfig::serial();
    serial.partition = PartitionConfig {
        num_partitions: 4,
        halo_hops: 1,
        cache_bits: 4,
    };
    let mut threaded = serial.clone();
    threaded.parallelism = ParallelismConfig {
        threads: 8,
        min_blocks_per_shard: 1,
    };
    let a = train_partitioned(&ds, &q, &serial, 9).unwrap();
    let b = train_partitioned(&ds, &q, &threaded, 9).unwrap();
    assert_eq!(a.result.final_train_loss, b.result.final_train_loss);
    assert_eq!(a.result.best_val_loss, b.result.best_val_loss);
    assert_eq!(a.result.test_accuracy, b.result.test_accuracy);
    assert_eq!(a.peak_resident_bytes, b.peak_resident_bytes);
    assert_eq!(a.cache_bytes, b.cache_bytes);
}

#[test]
fn subgraph_edges_are_exactly_the_induced_edges() {
    // Every edge of a partition's subgraph maps to a parent edge between
    // member nodes, and every parent edge between members appears.
    let ds = dataset(8);
    let ps = partition_dataset(&ds, 4, 1).unwrap();
    for p in &ps.parts {
        let members: HashSet<usize> = p.node_map.iter().copied().collect();
        // Parent edges between members (excluding self loops).
        let mut expected = HashSet::new();
        for &u in &p.node_map {
            for &v in ds.adj.row(u).0 {
                if v != u && members.contains(&v) {
                    expected.insert((u.min(v), u.max(v)));
                }
            }
        }
        let mut actual = HashSet::new();
        for local_u in 0..p.data.num_nodes() {
            let pu = p.node_map[local_u];
            for &local_v in p.data.adj.row(local_u).0 {
                let pv = p.node_map[local_v];
                if pu != pv {
                    actual.insert((pu.min(pv), pu.max(pv)));
                }
            }
        }
        assert_eq!(actual, expected);
    }
}
