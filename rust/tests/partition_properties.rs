//! Property tests for the graph partitioner (ISSUE 3): core-partition
//! exactness, halo correctness against an independent reference
//! implementation, and determinism — of the partitioner itself (pure
//! function, no threads) and of partitioned *training* across engine
//! thread counts.

use iexact::config::{DatasetSpec, ParallelismConfig, PartitionConfig, QuantConfig, TrainConfig};
use iexact::graph::{CsrMatrix, Dataset};
use iexact::partition::{partition_dataset, GraphPartition, PartitionSet, PartitionStore};
use iexact::pipeline::train_partitioned;
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;
use std::collections::HashSet;

fn dataset(seed: u64) -> Dataset {
    DatasetSpec::tiny().generate(seed)
}

/// Independent reference for the `h`-hop boundary neighborhood: plain
/// set-based BFS from the core over the parent adjacency.
fn reference_halo(ds: &Dataset, core: &[usize], hops: usize) -> Vec<usize> {
    let core_set: HashSet<usize> = core.iter().copied().collect();
    let mut reached: HashSet<usize> = core_set.clone();
    let mut frontier: Vec<usize> = core.to_vec();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &u in &frontier {
            for &v in ds.adj.row(u).0 {
                if v != u && !reached.contains(&v) {
                    reached.insert(v);
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    let mut halo: Vec<usize> = reached.difference(&core_set).copied().collect();
    halo.sort_unstable();
    halo
}

#[test]
fn every_node_in_exactly_one_core() {
    for seed in [1u64, 2, 3] {
        let ds = dataset(seed);
        for k in [2usize, 3, 4, 8, 13] {
            let ps = partition_dataset(&ds, k, 0).unwrap();
            let mut count = vec![0usize; ds.num_nodes()];
            for p in &ps.parts {
                for &u in &p.core {
                    count[u] += 1;
                }
            }
            for (u, &c) in count.iter().enumerate() {
                assert_eq!(c, 1, "seed {seed} k {k}: node {u} in {c} cores");
            }
        }
    }
}

#[test]
fn halo_equals_reference_h_hop_boundary() {
    let ds = dataset(4);
    for hops in [0usize, 1, 2, 3] {
        let ps = partition_dataset(&ds, 4, hops).unwrap();
        for (i, p) in ps.parts.iter().enumerate() {
            let expected = reference_halo(&ds, &p.core, hops);
            assert_eq!(
                p.halo, expected,
                "partition {i} at {hops} hops: halo does not match the true boundary"
            );
        }
    }
}

#[test]
fn node_map_merges_core_and_halo_and_masks_are_core_pure() {
    let ds = dataset(5);
    let ps = partition_dataset(&ds, 3, 2).unwrap();
    for p in &ps.parts {
        let mut expected: Vec<usize> = p.core.iter().chain(&p.halo).copied().collect();
        expected.sort_unstable();
        assert_eq!(p.node_map, expected);
        assert_eq!(p.core_mask.len(), p.node_map.len());
        for (local, &parent) in p.node_map.iter().enumerate() {
            let is_core = p.core.binary_search(&parent).is_ok();
            assert_eq!(p.core_mask[local], is_core);
            if is_core {
                // Core nodes keep their parent split membership.
                assert_eq!(p.data.train_mask[local], ds.train_mask[parent]);
                assert_eq!(p.data.val_mask[local], ds.val_mask[parent]);
                assert_eq!(p.data.test_mask[local], ds.test_mask[parent]);
            } else {
                assert!(
                    !p.data.train_mask[local]
                        && !p.data.val_mask[local]
                        && !p.data.test_mask[local],
                    "halo node {parent} kept a split"
                );
            }
            // Features and labels line up with the parent.
            assert_eq!(p.data.labels[local], ds.labels[parent]);
            assert_eq!(p.data.features.row(local), ds.features.row(parent));
        }
    }
}

fn fingerprint(ps: &PartitionSet) -> Vec<(Vec<usize>, Vec<usize>)> {
    ps.parts
        .iter()
        .map(|p| (p.core.clone(), p.halo.clone()))
        .collect()
}

#[test]
fn partitioning_is_deterministic() {
    let ds = dataset(6);
    let a = partition_dataset(&ds, 4, 1).unwrap();
    for _ in 0..3 {
        let b = partition_dataset(&ds, 4, 1).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_eq!(a.cut_edges, b.cut_edges);
    }
    // Regenerating the dataset (same seed) gives the same partitioning.
    let ds2 = dataset(6);
    let c = partition_dataset(&ds2, 4, 1).unwrap();
    assert_eq!(fingerprint(&a), fingerprint(&c));
}

#[test]
fn partitioned_training_is_identical_across_thread_counts() {
    // The partitioner draws no randomness and spawns no threads; the
    // trainer's engine threading is a pure speed knob. Together:
    // partitioned training at 1 vs 8 workers must agree bit-for-bit.
    let ds = dataset(7);
    let q = QuantConfig::int2_blockwise(4);
    let mut serial = TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 6,
        lr: 0.02,
        eval_every: 3,
        seeds: vec![0],
        ..TrainConfig::default()
    };
    serial.parallelism = ParallelismConfig::serial();
    serial.partition = PartitionConfig {
        num_partitions: 4,
        halo_hops: 1,
        cache_bits: 4,
    };
    let mut threaded = serial.clone();
    threaded.parallelism = ParallelismConfig {
        threads: 8,
        min_blocks_per_shard: 1,
        ..ParallelismConfig::default()
    };
    let a = train_partitioned(&ds, &q, &serial, 9).unwrap();
    let b = train_partitioned(&ds, &q, &threaded, 9).unwrap();
    assert_eq!(a.result.final_train_loss, b.result.final_train_loss);
    assert_eq!(a.result.best_val_loss, b.result.best_val_loss);
    assert_eq!(a.result.test_accuracy, b.result.test_accuracy);
    assert_eq!(a.peak_resident_bytes, b.peak_resident_bytes);
    assert_eq!(a.cache_bytes, b.cache_bytes);
}

// ---------------------------------------------------------------------------
// Chunk-store properties (ISSUE 6): the on-disk partition format must
// round-trip arbitrary valid graphs byte-exactly and reject foreign
// manifests by name.
// ---------------------------------------------------------------------------

fn store_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("iexact_store_prop_{name}_{}", std::process::id()))
}

/// Mirror of the store's trailer hash, so tests can re-seal a patched
/// manifest and prove the *targeted* validation fires (not the checksum).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Field-by-field bitwise equality (f32 payloads compared as bits, so
/// the check is genuinely byte-exact, not just `==`-exact).
fn assert_parts_bit_equal(a: &GraphPartition, b: &GraphPartition, what: &str) {
    assert_eq!(a.core, b.core, "{what}: core");
    assert_eq!(a.halo, b.halo, "{what}: halo");
    assert_eq!(a.node_map, b.node_map, "{what}: node_map");
    assert_eq!(a.core_mask, b.core_mask, "{what}: core_mask");
    let (da, db) = (&a.data, &b.data);
    assert_eq!(da.name, db.name, "{what}: name");
    assert_eq!(da.num_classes, db.num_classes, "{what}: num_classes");
    assert_eq!(da.labels, db.labels, "{what}: labels");
    assert_eq!(da.train_mask, db.train_mask, "{what}: train_mask");
    assert_eq!(da.val_mask, db.val_mask, "{what}: val_mask");
    assert_eq!(da.test_mask, db.test_mask, "{what}: test_mask");
    assert_eq!(da.adj.n_rows, db.adj.n_rows, "{what}: adj rows");
    assert_eq!(da.adj.n_cols, db.adj.n_cols, "{what}: adj cols");
    assert_eq!(da.adj.row_ptr, db.adj.row_ptr, "{what}: row_ptr");
    assert_eq!(da.adj.col_idx, db.adj.col_idx, "{what}: col_idx");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&da.adj.values), bits(&db.adj.values), "{what}: adj values");
    assert_eq!(
        bits(da.features.as_slice()),
        bits(db.features.as_slice()),
        "{what}: features"
    );
}

/// A structurally valid but adversarial dataset: random ragged-degree
/// CSR with ~1/4 zero-degree nodes and an arbitrary feature width.
fn random_dataset(rng: &mut Pcg64, n: usize, f: usize, classes: usize) -> Dataset {
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..n {
        if rng.next_f32() < 0.25 {
            row_ptr.push(col_idx.len()); // isolated node
            continue;
        }
        let deg = 1 + (rng.next_u64() % 4) as usize;
        let mut cols: Vec<usize> = (0..deg).map(|_| rng.next_u64() as usize % n).collect();
        cols.sort_unstable();
        cols.dedup();
        for c in cols {
            col_idx.push(c);
            values.push(rng.next_f32() * 2.0 - 1.0);
        }
        row_ptr.push(col_idx.len());
    }
    let adj = CsrMatrix {
        n_rows: n,
        n_cols: n,
        row_ptr,
        col_idx,
        values,
    };
    let features = Matrix::from_fn(n, f, |_, _| rng.next_f32() * 2.0 - 1.0);
    let labels = (0..n).map(|_| (rng.next_u64() % classes as u64) as u32).collect();
    let mut train_mask = vec![false; n];
    let mut val_mask = vec![false; n];
    let mut test_mask = vec![false; n];
    for i in 0..n {
        match rng.next_u64() % 4 {
            0 => train_mask[i] = true,
            1 => val_mask[i] = true,
            2 => test_mask[i] = true,
            _ => {}
        }
    }
    Dataset {
        name: format!("prop-{n}x{f}"),
        adj,
        features,
        labels,
        num_classes: classes,
        train_mask,
        val_mask,
        test_mask,
    }
}

#[test]
fn chunk_store_roundtrips_random_graphs_byte_exact() {
    let mut rng = Pcg64::new(0xC0FFEE);
    // Ragged feature widths on purpose: 1 scalar up to a prime width.
    for (case, &(n, f, k, halo)) in [(40usize, 1usize, 2usize, 0usize), (60, 7, 4, 1), (90, 13, 5, 2)]
        .iter()
        .enumerate()
    {
        let ds = random_dataset(&mut rng, n, f, 5);
        ds.validate().unwrap();
        let parts = partition_dataset(&ds, k, halo).unwrap();
        let dir = store_dir(&format!("rt{case}"));
        let created = PartitionStore::create(&parts, &dir).unwrap();
        let opened = PartitionStore::open(&dir).unwrap();
        assert_eq!(opened.num_partitions(), k);
        for p in 0..k {
            let what = format!("case {case} partition {p}");
            assert_parts_bit_equal(&parts.parts[p], &created.load_partition(p).unwrap(), &what);
            assert_parts_bit_equal(&parts.parts[p], &opened.load_partition(p).unwrap(), &what);
            // The manifest's residency figure is the loader's contract
            // with the budget check — it must equal the decoded size.
            assert_eq!(opened.resident_bytes(p), parts.parts[p].nbytes(), "{what}");
        }
        // Writing the same partitioning again is byte-identical on disk:
        // the format has no timestamps, padding junk, or map ordering.
        let dir2 = store_dir(&format!("rt{case}_again"));
        PartitionStore::create(&parts, &dir2).unwrap();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            assert_eq!(
                std::fs::read(dir.join(&name)).unwrap(),
                std::fs::read(dir2.join(&name)).unwrap(),
                "case {case}: {name:?} not deterministic"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }
}

#[test]
fn chunk_store_roundtrips_empty_partitions() {
    // A legal degenerate: a partition whose core and halo are empty
    // (k exceeds the populated communities). The store must carry it.
    let mut rng = Pcg64::new(99);
    let ds = random_dataset(&mut rng, 24, 3, 4);
    let mut parts = partition_dataset(&ds, 2, 1).unwrap();
    parts.parts.push(GraphPartition {
        core: vec![],
        halo: vec![],
        data: Dataset {
            name: "empty".into(),
            adj: CsrMatrix {
                n_rows: 0,
                n_cols: 0,
                row_ptr: vec![0],
                col_idx: vec![],
                values: vec![],
            },
            features: Matrix::zeros(0, 3),
            labels: vec![],
            num_classes: 4,
            train_mask: vec![],
            val_mask: vec![],
            test_mask: vec![],
        },
        node_map: vec![],
        core_mask: vec![],
    });
    let dir = store_dir("empty");
    PartitionStore::create(&parts, &dir).unwrap();
    let opened = PartitionStore::open(&dir).unwrap();
    assert_eq!(opened.num_partitions(), 3);
    for p in 0..3 {
        assert_parts_bit_equal(
            &parts.parts[p],
            &opened.load_partition(p).unwrap(),
            &format!("partition {p}"),
        );
    }
    assert_eq!(opened.core_train_count(2), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_rejects_version_and_endianness_mismatch() {
    let mut rng = Pcg64::new(7);
    let ds = random_dataset(&mut rng, 32, 4, 4);
    let parts = partition_dataset(&ds, 2, 1).unwrap();
    let dir = store_dir("foreign");
    PartitionStore::create(&parts, &dir).unwrap();
    let mpath = dir.join("manifest.bin");
    let pristine = std::fs::read(&mpath).unwrap();

    // Patch a field inside the sealed body, then re-seal with a fresh
    // trailer so the *named* validation fires rather than the checksum.
    let reseal = |offset: usize, field: [u8; 4]| {
        let mut bytes = pristine.clone();
        bytes[offset..offset + 4].copy_from_slice(&field);
        let body_len = bytes.len() - 8;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&mpath, &bytes).unwrap();
    };

    // Layout: 8-byte magic, then version u32, then endianness tag u32.
    reseal(8, 99u32.to_le_bytes());
    let msg = PartitionStore::open(&dir).unwrap_err().to_string();
    assert!(msg.contains("version"), "want a version error, got: {msg}");
    assert!(msg.contains("99"), "{msg}");

    reseal(12, [0x01, 0x02, 0x03, 0x04]); // the tag as a big-endian writer emits it
    let msg = PartitionStore::open(&dir).unwrap_err().to_string();
    assert!(msg.contains("endianness"), "want an endianness error, got: {msg}");

    // Restoring the pristine bytes restores the store.
    std::fs::write(&mpath, &pristine).unwrap();
    assert!(PartitionStore::open(&dir).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn subgraph_edges_are_exactly_the_induced_edges() {
    // Every edge of a partition's subgraph maps to a parent edge between
    // member nodes, and every parent edge between members appears.
    let ds = dataset(8);
    let ps = partition_dataset(&ds, 4, 1).unwrap();
    for p in &ps.parts {
        let members: HashSet<usize> = p.node_map.iter().copied().collect();
        // Parent edges between members (excluding self loops).
        let mut expected = HashSet::new();
        for &u in &p.node_map {
            for &v in ds.adj.row(u).0 {
                if v != u && members.contains(&v) {
                    expected.insert((u.min(v), u.max(v)));
                }
            }
        }
        let mut actual = HashSet::new();
        for local_u in 0..p.data.num_nodes() {
            let pu = p.node_map[local_u];
            for &local_v in p.data.adj.row(local_u).0 {
                let pv = p.node_map[local_v];
                if pu != pv {
                    actual.insert((pu.min(pv), pu.max(pv)));
                }
            }
        }
        assert_eq!(actual, expected);
    }
}
