//! Distributed training parity and fault-injection suite (ISSUE 8).
//!
//! Multi-process `train_distributed` must be **bit-identical** to
//! single-process `train_partitioned` at any worker count — same loss
//! curves, same final weights, byte-identical checkpoint state — while
//! halo/eval activations cross process boundaries as packed quantized
//! codes (asserted well under half the dense-f32 bytes). Killing a
//! worker mid-epoch must change nothing but the reassignment tally,
//! and garbage peers must surface *named* protocol errors.
//!
//! Workers run as in-process threads over real localhost TCP sockets —
//! the exact same `run_worker` entry the spawned `iexact train
//! --worker-rank` processes use.

use iexact::checkpoint::{load_state, state_to_bytes, TrainState};
use iexact::config::{
    AllocStrategy, AllocationConfig, DatasetSpec, PartitionConfig, QuantConfig, TrainConfig,
};
use iexact::coordinator::dist::{run_worker, train_distributed, DistTrainOutcome, WorkerOptions};
use iexact::pipeline::{train_partitioned_span, PartitionTrainResult};
use std::net::TcpListener;

const DATASET_SEED: u64 = 1;
const SEED: u64 = 7;

fn spec() -> DatasetSpec {
    DatasetSpec::tiny()
}

fn base_cfg(k: usize, workers: usize, adaptive: bool) -> TrainConfig {
    let mut cfg = TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 6,
        lr: 0.02,
        eval_every: 2,
        seeds: vec![SEED],
        partition: PartitionConfig {
            num_partitions: k,
            halo_hops: 1,
            cache_bits: 2,
            ..PartitionConfig::default()
        },
        ..TrainConfig::default()
    };
    cfg.distributed.workers = workers;
    if adaptive {
        cfg.allocation = AllocationConfig {
            strategy: AllocStrategy::Greedy,
            budget_bits: 2.5,
            realloc_interval_epochs: 3,
            min_bits: 1,
            max_bits: 8,
        };
    }
    cfg
}

/// Drive a leader with `opts.len()` in-process worker threads connected
/// over real TCP.
fn run_dist(
    quant: &QuantConfig,
    cfg: &TrainConfig,
    resume: Option<TrainState>,
    opts: Vec<WorkerOptions>,
) -> iexact::Result<DistTrainOutcome> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handles: Vec<_> = opts
        .into_iter()
        .enumerate()
        .map(|(rank, o)| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, rank as u32, &o))
        })
        .collect();
    let result = train_distributed(&listener, &spec(), DATASET_SEED, quant, cfg, SEED, resume);
    for h in handles {
        // Workers may exit Err (fault injection, leader-side failure);
        // the leader result is what the test judges.
        let _ = h.join().unwrap();
    }
    result
}

fn assert_identical(a: &PartitionTrainResult, b: &PartitionTrainResult, what: &str) {
    assert_eq!(
        a.result.curve.epochs, b.result.curve.epochs,
        "{what}: eval schedule diverged"
    );
    assert_eq!(
        a.result.curve.train_loss, b.result.curve.train_loss,
        "{what}: train-loss curve diverged"
    );
    assert_eq!(
        a.result.curve.val_loss, b.result.curve.val_loss,
        "{what}: val-loss curve diverged"
    );
    assert_eq!(
        a.result.curve.val_accuracy, b.result.curve.val_accuracy,
        "{what}: val-accuracy curve diverged"
    );
    assert_eq!(
        a.result.final_train_loss, b.result.final_train_loss,
        "{what}: final loss diverged"
    );
    assert_eq!(
        a.result.test_accuracy, b.result.test_accuracy,
        "{what}: test accuracy diverged"
    );
    assert_eq!(
        a.result.stash_bytes, b.result.stash_bytes,
        "{what}: peak stash diverged"
    );
    assert_eq!(a.cache_bytes, b.cache_bytes, "{what}: cache bytes diverged");
    assert_eq!(a.halo_nodes, b.halo_nodes, "{what}: halo nodes diverged");
    assert_eq!(
        a.edge_cut_fraction, b.edge_cut_fraction,
        "{what}: edge cut diverged"
    );
    for (l, (wa, wb)) in a.model.weights.iter().zip(&b.model.weights).enumerate() {
        assert_eq!(
            wa.as_slice(),
            wb.as_slice(),
            "{what}: layer {l} weights diverged"
        );
    }
}

#[test]
fn distributed_is_bit_identical_at_any_worker_count() {
    let quant = QuantConfig::int2_blockwise(4);
    for adaptive in [false, true] {
        let single = base_cfg(4, 0, adaptive);
        let ds = spec().generate(DATASET_SEED);
        let (reference, ref_state) =
            train_partitioned_span(&ds, &quant, &single, SEED, None).unwrap();
        for workers in [1usize, 2, 4] {
            let tag = format!("a{}_w{workers}", adaptive as u8);
            let cfg = base_cfg(4, workers, adaptive);
            let out = run_dist(
                &quant,
                &cfg,
                None,
                vec![WorkerOptions::default(); workers],
            )
            .unwrap();
            assert_identical(&reference, &out.result, &tag);
            // The canonical state serialization must agree to the byte.
            assert_eq!(
                state_to_bytes(&ref_state),
                state_to_bytes(&out.state),
                "{tag}: checkpoint state bytes diverged"
            );
            assert_eq!(
                out.reassigned_partitions, 0,
                "{tag}: healthy run reassigned partitions"
            );
            // The tentpole's wire claim: halo/eval traffic crosses as
            // packed INT2 codes at well under half the f32 bytes.
            assert!(out.wire.halo_payload_bytes > 0, "{tag}: no wire traffic");
            assert!(
                out.wire.halo_payload_bytes * 2 < out.wire.halo_f32_bytes,
                "{tag}: wire bytes {} not < 0.5x f32 bytes {}",
                out.wire.halo_payload_bytes,
                out.wire.halo_f32_bytes
            );
        }
    }
}

#[test]
fn killed_worker_is_reassigned_and_changes_nothing() {
    let quant = QuantConfig::int2_blockwise(4);
    let ds = spec().generate(DATASET_SEED);
    let (reference, ref_state) =
        train_partitioned_span(&ds, &quant, &base_cfg(4, 0, false), SEED, None).unwrap();
    // Worker 1 vanishes mid-epoch after its third training step; the
    // survivor must absorb its partitions with identical numbers.
    let opts = vec![
        WorkerOptions::default(),
        WorkerOptions {
            fail_after_steps: Some(3),
            ..Default::default()
        },
    ];
    let out = run_dist(&quant, &base_cfg(4, 2, false), None, opts).unwrap();
    assert!(
        out.reassigned_partitions > 0,
        "the killed worker's partitions were never reassigned"
    );
    assert_identical(&reference, &out.result, "killed worker");
    assert_eq!(
        state_to_bytes(&ref_state),
        state_to_bytes(&out.state),
        "killed worker: checkpoint state bytes diverged"
    );
}

/// The elastic-restart tentpole: worker 1 crashes mid-epoch, the
/// leader's respawn hook brings up a `--rejoin` replacement, and the
/// run still finishes bit-identical to the uninterrupted
/// single-process reference — in both fixed and adaptive-allocation
/// modes (the latter exercises `plans_from` re-solving on rejoin).
#[test]
fn crashed_worker_restarts_rejoins_and_stays_bit_identical() {
    use iexact::coordinator::dist::{train_distributed_with, DistHooks};
    let quant = QuantConfig::int2_blockwise(4);
    let ds = spec().generate(DATASET_SEED);
    for adaptive in [false, true] {
        let tag = format!("restart_a{}", adaptive as u8);
        let (reference, ref_state) =
            train_partitioned_span(&ds, &quant, &base_cfg(4, 0, adaptive), SEED, None).unwrap();
        let cfg = base_cfg(4, 2, adaptive);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker_opts = vec![
            WorkerOptions::default(),
            WorkerOptions {
                fail_after_steps: Some(3),
                ..Default::default()
            },
        ];
        let handles: Vec<_> = worker_opts
            .into_iter()
            .enumerate()
            .map(|(rank, o)| {
                let addr = addr.clone();
                std::thread::spawn(move || run_worker(&addr, rank as u32, &o))
            })
            .collect();
        let respawned = std::cell::RefCell::new(Vec::new());
        let out = {
            let hooks = DistHooks {
                respawn: Some(Box::new(|rank| {
                    let addr = addr.clone();
                    respawned.borrow_mut().push(std::thread::spawn(move || {
                        run_worker(
                            &addr,
                            rank,
                            &WorkerOptions {
                                rejoin: true,
                                ..Default::default()
                            },
                        )
                    }));
                    Ok(())
                })),
            };
            train_distributed_with(
                &listener,
                &spec(),
                DATASET_SEED,
                &quant,
                &cfg,
                SEED,
                None,
                hooks,
            )
            .unwrap()
        };
        for h in handles {
            let _ = h.join().unwrap();
        }
        for h in respawned.into_inner() {
            let _ = h.join().unwrap();
        }
        assert!(out.faults.deaths >= 1, "{tag}: the crash was never noticed");
        assert!(
            out.faults.restarts >= 1,
            "{tag}: the dead worker was never restarted"
        );
        assert_identical(&reference, &out.result, &tag);
        assert_eq!(
            state_to_bytes(&ref_state),
            state_to_bytes(&out.state),
            "{tag}: checkpoint state bytes diverged"
        );
    }
}

/// A respawn hook that cannot deliver a replacement consumes restart
/// budget but must not fail the run: the rank stays dead, partitions
/// reassign, and the numbers still match the reference.
#[test]
fn failed_respawn_degrades_to_reassignment() {
    use iexact::coordinator::dist::{train_distributed_with, DistHooks};
    let quant = QuantConfig::int2_blockwise(4);
    let ds = spec().generate(DATASET_SEED);
    let (reference, _) =
        train_partitioned_span(&ds, &quant, &base_cfg(4, 0, false), SEED, None).unwrap();
    let cfg = base_cfg(4, 2, false);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker_opts = vec![
        WorkerOptions::default(),
        WorkerOptions {
            fail_after_steps: Some(3),
            ..Default::default()
        },
    ];
    let handles: Vec<_> = worker_opts
        .into_iter()
        .enumerate()
        .map(|(rank, o)| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, rank as u32, &o))
        })
        .collect();
    let hooks = DistHooks {
        respawn: Some(Box::new(|rank| {
            Err(iexact::Error::Runtime(format!(
                "injected respawn failure for worker {rank}"
            )))
        })),
    };
    let out = train_distributed_with(
        &listener,
        &spec(),
        DATASET_SEED,
        &quant,
        &cfg,
        SEED,
        None,
        hooks,
    )
    .unwrap();
    for h in handles {
        let _ = h.join().unwrap();
    }
    assert!(out.faults.deaths >= 1, "the crash was never noticed");
    assert!(
        out.reassigned_partitions > 0,
        "the dead worker's partitions were never reassigned"
    );
    assert_identical(&reference, &out.result, "failed respawn");
}

#[test]
fn all_workers_dead_is_a_named_error() {
    let quant = QuantConfig::int2_blockwise(4);
    let opts = vec![WorkerOptions {
        fail_after_steps: Some(0),
        ..Default::default()
    }];
    let err = run_dist(&quant, &base_cfg(2, 1, false), None, opts).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("dist protocol"), "{msg}");
    assert!(msg.contains("workers are dead"), "{msg}");
}

#[test]
fn garbage_handshake_is_a_named_protocol_error() {
    let quant = QuantConfig::int2_blockwise(4);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let peer = std::thread::spawn(move || {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        // Not an iexact frame: wrong magic from the first byte.
        s.write_all(&[0x47u8; 64]).unwrap();
        // Hold the socket open until the leader rejects us.
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    });
    let err = train_distributed(
        &listener,
        &spec(),
        DATASET_SEED,
        &quant,
        &base_cfg(2, 1, false),
        SEED,
        None,
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("dist protocol"), "{msg}");
    assert!(msg.contains("magic"), "{msg}");
    peer.join().unwrap();
}

#[test]
fn out_of_range_worker_rank_is_rejected() {
    let quant = QuantConfig::int2_blockwise(4);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let w = std::thread::spawn(move || {
        let _ = run_worker(&addr, 7, &WorkerOptions::default());
    });
    let err = train_distributed(
        &listener,
        &spec(),
        DATASET_SEED,
        &quant,
        &base_cfg(2, 1, false),
        SEED,
        None,
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("rank 7 out of range"), "{msg}");
    w.join().unwrap();
}

#[test]
fn checkpoint_resume_reproduces_the_uninterrupted_run() {
    let quant = QuantConfig::int2_blockwise(4);
    let ds = spec().generate(DATASET_SEED);
    let mut full = base_cfg(4, 0, false);
    full.epochs = 8;
    let (_, ref_state) = train_partitioned_span(&ds, &quant, &full, SEED, None).unwrap();

    let ckpt = std::env::temp_dir()
        .join(format!("iexact_dist_resume_{}.ckpt", std::process::id()));
    let ckpt_str = ckpt.to_str().unwrap().to_string();

    // Leg A: epochs [0, 4) distributed, checkpointing every 2 epochs —
    // then pretend the leader was killed and resume from disk.
    let mut leg_a = base_cfg(4, 2, false);
    leg_a.epochs = 4;
    leg_a.distributed.checkpoint_path = Some(ckpt_str.clone());
    leg_a.distributed.checkpoint_every_epochs = 2;
    run_dist(&quant, &leg_a, None, vec![WorkerOptions::default(); 2]).unwrap();
    let saved = load_state(&ckpt).unwrap();
    assert_eq!(saved.epoch, 4, "leg A should have checkpointed at epoch 4");

    // Leg B: resume at epoch 4, run to 8, still checkpointing.
    let mut leg_b = base_cfg(4, 2, false);
    leg_b.epochs = 8;
    leg_b.distributed.checkpoint_path = Some(ckpt_str.clone());
    leg_b.distributed.checkpoint_every_epochs = 2;
    let out = run_dist(
        &quant,
        &leg_b,
        Some(saved),
        vec![WorkerOptions::default(); 2],
    )
    .unwrap();
    assert_eq!(
        state_to_bytes(&ref_state),
        state_to_bytes(&out.state),
        "resumed run diverged from the uninterrupted single-process run"
    );
    // The final on-disk checkpoint is the same state, byte for byte.
    let final_saved = load_state(&ckpt).unwrap();
    assert_eq!(
        state_to_bytes(&final_saved),
        state_to_bytes(&out.state),
        "final checkpoint file disagrees with the returned state"
    );
    std::fs::remove_file(&ckpt).ok();
}
