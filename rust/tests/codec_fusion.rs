//! Property suite for the word-parallel codec (ISSUE 5).
//!
//! The SWAR pack/unpack folds, the fused `quantize_pack_block`
//! (stochastic rounding straight into packed bytes) and the fused
//! `unpack_dequantize_block` (packed bytes → `f32` through per-block
//! value LUTs) must be **bit-identical** to the pre-fusion two-pass
//! codec kept in `iexact::quant::reference` — at every width (1/2/4/8),
//! on ragged tails, constant blocks, non-uniform bins, heterogeneous
//! `BitPlan`s, and at every thread count (1/2/4/7). The suite also
//! proves the structural claim: the fused paths draw **no** byte
//! scratch from the `BufferPool` (the `max_byte_take` stat), so the
//! intermediate `u8` code buffer is gone, not merely recycled.

use iexact::alloc::BitPlan;
use iexact::engine::QuantEngine;
use iexact::graph::CsrMatrix;
use iexact::memory::BufferPool;
use iexact::quant::{reference, BinSpec, CodecIsa};
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;

/// The thread counts the acceptance criteria name.
const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 4.0 - 2.0)
}

#[test]
fn swar_pack_unpack_matches_naive_reference() {
    let mut rng = Pcg64::new(0xC0DE);
    for bits in [1u32, 2, 4, 8] {
        let max = (1u32 << bits) as u64;
        for n in [0usize, 1, 3, 7, 8, 9, 16, 17, 31, 64, 100, 333] {
            let codes: Vec<u8> = (0..n).map(|_| rng.next_bounded(max) as u8).collect();
            let swar = iexact::quant::pack_codes(&codes, bits).unwrap();
            let naive = reference::pack_codes(&codes, bits).unwrap();
            assert_eq!(swar, naive, "pack bits={bits} n={n}");
            assert_eq!(
                iexact::quant::unpack_codes(&swar, bits, n).unwrap(),
                reference::unpack_codes(&naive, bits, n).unwrap(),
                "unpack bits={bits} n={n}"
            );
        }
    }
}

#[test]
fn fused_fixed_width_matches_reference_at_every_thread_count() {
    // Aligned group lengths ride the fused quantize-pack path; the
    // non-aligned ones exercise the two-pass fallback. Both must equal
    // the serial reference byte-for-byte, and so must the fused
    // dequantize, at every width and thread count. 527 = 17·31 scalars
    // leaves a ragged final block for every group length here.
    let h = sample_matrix(17, 31, 0xBEE);
    for bits in [1u32, 2, 4, 8] {
        for group_len in [8usize, 20, 7, 64] {
            let seed = 0x5EED ^ ((bits as u64) << 8) ^ (group_len as u64);
            let want = reference::quantize_grouped_seeded(
                &h,
                group_len,
                bits,
                &BinSpec::Uniform,
                seed,
            )
            .unwrap();
            let want_deq = reference::dequantize(&want).unwrap();
            for threads in THREAD_COUNTS {
                let engine = QuantEngine::with_threads(threads);
                let got = engine
                    .quantize_seeded(&h, group_len, bits, &BinSpec::Uniform, seed)
                    .unwrap();
                assert_eq!(
                    got.packed, want.packed,
                    "packed bits={bits} G={group_len} t={threads}"
                );
                assert_eq!(got.zeros, want.zeros);
                assert_eq!(got.ranges, want.ranges);
                let deq = engine.dequantize(&got).unwrap();
                assert_eq!(
                    deq.as_slice(),
                    want_deq.as_slice(),
                    "dequant bits={bits} G={group_len} t={threads}"
                );
            }
        }
    }
}

#[test]
fn fused_vm_bins_match_reference() {
    let h = sample_matrix(24, 16, 0xFACE);
    let bins = BinSpec::int2_vm(1.2, 1.8).unwrap();
    let want = reference::quantize_grouped_seeded(&h, 32, 2, &bins, 99).unwrap();
    let want_deq = reference::dequantize(&want).unwrap();
    for threads in THREAD_COUNTS {
        let engine = QuantEngine::with_threads(threads);
        let got = engine.quantize_seeded(&h, 32, 2, &bins, 99).unwrap();
        assert_eq!(got.packed, want.packed, "t={threads}");
        assert_eq!(
            engine.dequantize(&got).unwrap().as_slice(),
            want_deq.as_slice(),
            "t={threads}"
        );
    }
}

#[test]
fn constant_blocks_stay_exact_under_fusion() {
    // A constant tensor must pack to all-zero codes and dequantize back
    // to the constant exactly — the zero-fill path of the fused packer.
    let h = Matrix::from_fn(9, 14, |_, _| -1.25);
    for bits in [1u32, 2, 4, 8] {
        for group_len in [8usize, 9, 126] {
            let want =
                reference::quantize_grouped_seeded(&h, group_len, bits, &BinSpec::Uniform, 1)
                    .unwrap();
            let got = QuantEngine::with_threads(4)
                .quantize_seeded(&h, group_len, bits, &BinSpec::Uniform, 1)
                .unwrap();
            assert_eq!(got.packed, want.packed, "bits={bits} G={group_len}");
            assert!(got.packed.iter().all(|&b| b == 0));
            let deq = got.dequantize().unwrap();
            assert_eq!(deq.as_slice(), h.as_slice(), "bits={bits} G={group_len}");
        }
    }
}

/// A deliberately adversarial plan: every width, ragged final block.
fn hetero_plan(num_blocks: usize, group_len: usize, seed: u64) -> BitPlan {
    let mut rng = Pcg64::new(seed);
    let bits: Vec<u8> = (0..num_blocks)
        .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
        .collect();
    BitPlan::new(bits, group_len).unwrap()
}

#[test]
fn fused_planned_matches_reference_at_every_thread_count() {
    // 1221 scalars at G=100 → 13 blocks, final block ragged (21).
    let h = sample_matrix(33, 37, 0xDEC0);
    let plan = hetero_plan(13, 100, 7);
    let want = reference::quantize_planned_seeded(&h, &plan, 0xfeed).unwrap();
    let want_deq = reference::dequantize_planned(&want).unwrap();
    for threads in THREAD_COUNTS {
        let engine = QuantEngine::with_threads(threads);
        let got = engine.quantize_planned_seeded(&h, &plan, 0xfeed).unwrap();
        assert_eq!(got.packed, want.packed, "t={threads}");
        assert_eq!(got.zeros, want.zeros, "t={threads}");
        assert_eq!(got.ranges, want.ranges, "t={threads}");
        let deq = engine.dequantize_planned(&got).unwrap();
        assert_eq!(deq.as_slice(), want_deq.as_slice(), "t={threads}");
    }
}

#[test]
fn fused_planned_uniform_plan_equals_fixed_width_bytes() {
    // A constant-width plan and the fixed-width engine must agree on
    // every byte — the two packers share one layout.
    let h = sample_matrix(32, 16, 0xAB);
    for bits in [1u32, 2, 4, 8] {
        let plan = BitPlan::uniform(bits, 16, 32).unwrap();
        let planned = QuantEngine::with_threads(3)
            .quantize_planned_seeded(&h, &plan, 5)
            .unwrap();
        let fixed = QuantEngine::serial()
            .quantize_seeded(&h, 32, bits, &BinSpec::Uniform, 5)
            .unwrap();
        assert_eq!(planned.packed, fixed.packed, "bits={bits}");
        assert_eq!(planned.zeros, fixed.zeros, "bits={bits}");
    }
}

#[test]
fn fused_paths_match_reference_under_every_forced_isa() {
    // The fusion bit-identity contract, re-proven per dispatch tier:
    // quantize→pack (fused and two-pass-fallback group lengths) and the
    // fused unpack→dequantize must equal the two-pass reference on every
    // ISA the host can run — uniform bins, VM bins, and a heterogeneous
    // plan. The deep geometry sweep lives in `codec_dispatch.rs`; this
    // pins the *engine-integrated* fused kernels specifically.
    let h = sample_matrix(17, 31, 0xBEE);
    let vm = BinSpec::int2_vm(1.2, 1.8).unwrap();
    let plan = hetero_plan(13, 100, 7);
    let want_planned = reference::quantize_planned_seeded(&h, &plan, 0xfeed).unwrap();
    let want_planned_deq = reference::dequantize_planned(&want_planned).unwrap();
    for isa in CodecIsa::available() {
        let engine = QuantEngine::with_threads(4).with_codec_isa(isa).unwrap();
        for (bits, bins) in [(1u32, &BinSpec::Uniform), (2, &vm), (4, &BinSpec::Uniform)] {
            // G=20 rides the fused quantize-pack path, G=7 the two-pass
            // fallback — both pack through the forced ISA now.
            for group_len in [20usize, 7] {
                let seed = 0xF05ED ^ ((bits as u64) << 8) ^ (group_len as u64);
                let want =
                    reference::quantize_grouped_seeded(&h, group_len, bits, bins, seed).unwrap();
                let got = engine.quantize_seeded(&h, group_len, bits, bins, seed).unwrap();
                assert_eq!(
                    got.packed, want.packed,
                    "packed isa={isa} bits={bits} G={group_len}"
                );
                assert_eq!(
                    engine.dequantize(&got).unwrap().as_slice(),
                    reference::dequantize(&want).unwrap().as_slice(),
                    "dequant isa={isa} bits={bits} G={group_len}"
                );
            }
        }
        let got = engine.quantize_planned_seeded(&h, &plan, 0xfeed).unwrap();
        assert_eq!(got.packed, want_planned.packed, "planned packed isa={isa}");
        assert_eq!(
            engine.dequantize_planned(&got).unwrap().as_slice(),
            want_planned_deq.as_slice(),
            "planned dequant isa={isa}"
        );
    }
}

fn ring_adjacency(n: usize) -> CsrMatrix {
    let mut edges = Vec::new();
    for i in 0..n {
        edges.push((i, (i + 1) % n, 0.5f32));
        edges.push((i, (i + 11) % n, 0.25f32));
        edges.push((i, i, 1.0f32));
    }
    CsrMatrix::from_edges(n, &edges).unwrap()
}

#[test]
fn dequantize_paths_draw_no_byte_scratch() {
    // The structural claim of the fusion: pure decode paths never take
    // a byte buffer from the pool — the decode→codes→floats double pass
    // is gone. (Float draws stay tile-bounded, as runtime_parity pins.)
    let n = 64;
    let r_dim = 16;
    let h = sample_matrix(n, r_dim, 0xD00D);
    let glen = 2 * r_dim;
    let plan = hetero_plan(n * r_dim / glen, glen, 3);
    let engine = QuantEngine::with_threads(4);
    let pt = engine.quantize_planned_seeded(&h, &plan, 11).unwrap();
    let ct = engine
        .quantize_seeded(&h, glen, 2, &BinSpec::Uniform, 11)
        .unwrap();
    let operand = sample_matrix(r_dim, 8, 0xD00E);
    let adj = ring_adjacency(n);

    let mut pool = BufferPool::new();
    let _ = engine.dequantize_pooled(&ct, &mut pool).unwrap();
    assert_eq!(pool.stats().max_byte_take, 0, "fixed dequantize");

    let mut pool = BufferPool::new();
    let _ = engine.dequantize_planned_pooled(&pt, &mut pool).unwrap();
    assert_eq!(pool.stats().max_byte_take, 0, "planned dequantize");

    let mut pool = BufferPool::new();
    let _ = engine.dequantize_matmul(&ct, &operand, &mut pool).unwrap();
    assert_eq!(pool.stats().max_byte_take, 0, "fused matmul");
    assert!(pool.stats().max_float_take <= glen);

    let mut pool = BufferPool::new();
    let _ = engine
        .dequantize_matmul_planned(&pt, &operand, &mut pool)
        .unwrap();
    assert_eq!(pool.stats().max_byte_take, 0, "fused planned matmul");

    let mut pool = BufferPool::new();
    let _ = engine.dequantize_spmm_planned(&adj, &pt, &mut pool).unwrap();
    assert_eq!(pool.stats().max_byte_take, 0, "fused spmm");
    assert!(pool.stats().max_float_take <= glen);
}

#[test]
fn quantize_draws_only_the_packed_buffer() {
    // On the quantize side the pool's sole byte take is the packed
    // output — 4× smaller than the scalar count at INT2, which is only
    // possible if no full-size code scratch exists.
    let h = sample_matrix(64, 16, 0xF00);
    let n = 64 * 16;
    let engine = QuantEngine::with_threads(4);

    let mut pool = BufferPool::new();
    let mut rng = Pcg64::new(1);
    let ct = engine
        .quantize_pooled(&h, 32, 2, &BinSpec::Uniform, &mut rng, &mut pool)
        .unwrap();
    assert_eq!(ct.packed.len(), n / 4);
    assert_eq!(pool.stats().max_byte_take, n / 4, "{:?}", pool.stats());

    let mut pool = BufferPool::new();
    let plan = BitPlan::uniform(2, n / 32, 32).unwrap();
    let mut rng = Pcg64::new(2);
    let pt = engine
        .quantize_planned_pooled(&h, &plan, &mut rng, &mut pool)
        .unwrap();
    assert_eq!(pt.packed.len(), n / 4);
    assert_eq!(pool.stats().max_byte_take, n / 4, "{:?}", pool.stats());
}

#[test]
fn fallback_two_pass_path_still_recycles_scratch() {
    // Non-byte-aligned fixed-width groups (G·bits % 8 ≠ 0) take the
    // two-pass fallback: it still draws (and returns) the n-byte code
    // scratch, and stays bit-identical to the reference.
    let h = sample_matrix(10, 10, 0xF01);
    let engine = QuantEngine::serial();
    let mut pool = BufferPool::new();
    let mut rng = Pcg64::new(3);
    let seed_probe = Pcg64::new(3).next_u64();
    let ct = engine
        .quantize_pooled(&h, 7, 2, &BinSpec::Uniform, &mut rng, &mut pool)
        .unwrap();
    assert_eq!(pool.stats().max_byte_take, 100, "{:?}", pool.stats());
    let want = reference::quantize_grouped_seeded(&h, 7, 2, &BinSpec::Uniform, seed_probe).unwrap();
    assert_eq!(ct.packed, want.packed);
    assert_eq!(ct.zeros, want.zeros);
}
