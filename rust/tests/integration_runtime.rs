//! Three-layer composition tests: load the JAX/Pallas AOT artifacts and
//! drive them from the Rust coordinator via PJRT.
//!
//! These tests require `make artifacts`; they skip (pass with a notice)
//! when the artifact directory is absent so a fresh checkout stays green.

use iexact::config::DatasetSpec;
use iexact::coordinator::AotCoordinator;
use iexact::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    // Tests run from the crate root.
    let p = std::path::PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/manifest.json missing (run `make artifacts`)");
        None
    }
}

fn aot_dataset(rt: &Runtime, name: &str) -> DatasetSpec {
    let entry = rt.manifest().get(name).unwrap();
    DatasetSpec {
        num_nodes: entry.meta["num_nodes"].parse().unwrap(),
        num_features: entry.meta["num_features"].parse().unwrap(),
        num_classes: entry.meta["num_classes"].parse().unwrap(),
        ..DatasetSpec::arxiv_like()
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).unwrap();
    let names = rt.artifact_names();
    for expected in [
        "train_step_arxiv_fp32",
        "train_step_arxiv_int2_exact",
        "train_step_arxiv_int2_g8",
        "train_step_arxiv_int2_g64",
        "train_step_arxiv_int2_vm",
        "eval_arxiv",
        "train_step_flickr_fp32",
        "eval_flickr",
    ] {
        assert!(
            names.iter().any(|n| n == expected),
            "missing artifact {expected}; have {names:?}"
        );
    }
}

#[test]
fn aot_train_step_decreases_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let name = "train_step_arxiv_int2_g8";
    let spec = aot_dataset(&rt, name);
    let ds = spec.generate(42);
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", "int2_g8", &ds, 0).unwrap();
    let first = coord.step("int2_g8").unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = coord.step("int2_g8").unwrap();
    }
    assert!(
        last < first * 0.9,
        "loss should drop: {first} -> {last}"
    );
}

#[test]
fn aot_eval_produces_valid_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let spec = aot_dataset(&rt, "eval_arxiv");
    let ds = spec.generate(42);
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", "fp32", &ds, 0).unwrap();
    let logits = coord.logits().unwrap();
    assert_eq!(logits.shape(), (ds.num_nodes(), ds.num_classes));
    assert!(logits.as_slice().iter().all(|v| v.is_finite()));
}

#[test]
fn aot_full_train_reaches_learnable_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let spec = aot_dataset(&rt, "train_step_arxiv_int2_g64");
    let ds = spec.generate(42);
    let chance = 1.0 / ds.num_classes as f64;
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", "int2_g64", &ds, 0).unwrap();
    let out = coord.train("int2_g64", &ds, 60, 10).unwrap();
    assert!(
        out.test_accuracy > 3.0 * chance,
        "acc {} vs chance {chance}",
        out.test_accuracy
    );
    assert!(out.epochs_per_sec > 0.0);
    assert!(!out.curve.is_empty());
}

#[test]
fn aot_vm_variant_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let spec = aot_dataset(&rt, "train_step_arxiv_int2_vm");
    let ds = spec.generate(42);
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", "int2_vm", &ds, 0).unwrap();
    let l1 = coord.step("int2_vm").unwrap();
    let l2 = coord.step("int2_vm").unwrap();
    assert!(l1.is_finite() && l2.is_finite());
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let bad = iexact::tensor::Matrix::zeros(2, 2);
    let err = rt.execute("eval_arxiv", &[&bad]);
    assert!(err.is_err(), "wrong arity must fail");
}

#[test]
fn runtime_stats_accumulate() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let spec = aot_dataset(&rt, "eval_arxiv");
    let ds = spec.generate(42);
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", "fp32", &ds, 0).unwrap();
    coord.logits().unwrap();
    coord.logits().unwrap();
    drop(coord);
    let stats = rt.stats("eval_arxiv");
    assert_eq!(stats.calls, 2);
    assert!(stats.total_secs > 0.0);
}
