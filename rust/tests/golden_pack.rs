//! Golden-fixture regression tests for the packed quantization formats.
//!
//! The packed byte layout (LSB-first code packing, per-block byte-aligned
//! heterogeneous blocks, `(zero, range)` f32 metadata) is a *persistence
//! format*: the activation cache, any future on-disk spill, and the
//! analytic memory model all assume it never drifts silently. These tests
//! quantize a fixed input under a fixed seed at every supported width —
//! 1/2/4/8-bit fixed plans plus a heterogeneous `BitPlan` — and compare
//! the serialized result **byte-exactly** against small binary fixtures
//! committed under `tests/golden/`.
//!
//! The fixtures were generated independently by
//! `scripts/make_golden_fixtures.py`, a bit-exact Python port of the
//! PCG64 stream addressing and the uniform-bins stochastic-rounding
//! kernel, so the Rust implementation is cross-checked against a second
//! implementation rather than against itself.
//!
//! If a format change is *intentional*, re-bless with:
//!
//! ```sh
//! IEXACT_BLESS=1 cargo test --test golden_pack
//! # or regenerate from the independent port:
//! python3 scripts/make_golden_fixtures.py rust/tests/golden
//! ```
//!
//! A missing fixture fails loudly too (regenerate with the script or
//! bless): auto-writing on absence would let a broken checkout bless
//! exactly the drift this suite exists to catch.

use iexact::alloc::{BitPlan, PlannedTensor};
use iexact::engine::QuantEngine;
use iexact::quant::{BinSpec, CodecIsa, CompressedTensor};
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;
use std::path::PathBuf;

/// Fixture geometry: 24x16 = 384 scalars, 12 blocks of 32.
const ROWS: usize = 24;
const COLS: usize = 16;
const GROUP_LEN: usize = 32;
/// Seed for the input values.
const DATA_SEED: u64 = 0xF1B0;
/// Seed keying the per-block stochastic-rounding streams.
const QUANT_SEED: u64 = 0x5EED_601D;

/// The fixed input: `next_f32() * 4 - 2` in row-major order. Every
/// arithmetic step is exact or IEEE-deterministic, so the Python
/// generator reproduces it bit-for-bit.
fn fixture_input() -> Matrix {
    let mut rng = Pcg64::new(DATA_SEED);
    Matrix::from_fn(ROWS, COLS, |_, _| rng.next_f32() * 4.0 - 2.0)
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialization protocol for fixed-width tensors (mirrored by
/// `scripts/make_golden_fixtures.py` — change both together).
fn serialize_fixed(ct: &CompressedTensor) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(b"IEXGFIX1");
    push_u32(&mut buf, ct.shape.0 as u32);
    push_u32(&mut buf, ct.shape.1 as u32);
    push_u32(&mut buf, ct.group_len as u32);
    push_u32(&mut buf, ct.bits);
    push_u64(&mut buf, ct.packed.len() as u64);
    buf.extend_from_slice(&ct.packed);
    push_u64(&mut buf, ct.zeros.len() as u64);
    push_f32s(&mut buf, &ct.zeros);
    push_f32s(&mut buf, &ct.ranges);
    buf
}

/// Serialization protocol for heterogeneous-plan tensors.
fn serialize_planned(pt: &PlannedTensor) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(b"IEXGPLN1");
    push_u32(&mut buf, pt.shape.0 as u32);
    push_u32(&mut buf, pt.shape.1 as u32);
    push_u32(&mut buf, pt.plan.group_len() as u32);
    push_u64(&mut buf, pt.plan.num_blocks() as u64);
    buf.extend_from_slice(pt.plan.bits());
    push_u64(&mut buf, pt.packed.len() as u64);
    buf.extend_from_slice(&pt.packed);
    push_u64(&mut buf, pt.zeros.len() as u64);
    push_f32s(&mut buf, &pt.zeros);
    push_f32s(&mut buf, &pt.ranges);
    buf
}

/// Compare `actual` against the committed fixture, blessing only when
/// `IEXACT_BLESS` is set. A *missing* fixture is a hard failure: the
/// fixtures are committed, so their absence means the regression
/// protection has been silently dropped (gitignore, broken checkout) —
/// auto-writing would bless exactly the drift this suite exists to
/// catch.
fn check_golden(name: &str, actual: &[u8]) {
    let path = golden_dir().join(format!("{name}.bin"));
    if std::env::var_os("IEXACT_BLESS").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        return;
    }
    assert!(
        path.exists(),
        "golden fixture '{name}' is missing from {}. Restore the committed \
         fixture, or regenerate with `python3 scripts/make_golden_fixtures.py \
         rust/tests/golden` / `IEXACT_BLESS=1 cargo test --test golden_pack`.",
        path.display()
    );
    let expected = std::fs::read(&path).unwrap();
    if expected != actual {
        let first_diff = expected
            .iter()
            .zip(actual)
            .position(|(a, b)| a != b)
            .unwrap_or(expected.len().min(actual.len()));
        panic!(
            "packed-format drift in golden fixture '{name}': expected {} bytes, got {}, \
             first difference at byte {first_diff}. If this change is intentional, \
             re-bless with `IEXACT_BLESS=1 cargo test --test golden_pack`.",
            expected.len(),
            actual.len()
        );
    }
}

/// The heterogeneous plan: 12 blocks cycling through every width.
fn hetero_plan() -> BitPlan {
    let bits: Vec<u8> = (0..12).map(|g| [1u8, 2, 4, 8][g % 4]).collect();
    BitPlan::new(bits, GROUP_LEN).unwrap()
}

#[test]
fn golden_fixed_width_2_4_8() {
    let h = fixture_input();
    for bits in [2u32, 4, 8] {
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, GROUP_LEN, bits, &BinSpec::Uniform, QUANT_SEED)
            .unwrap();
        // Sanity on the layout the fixture freezes.
        assert_eq!(ct.packed.len(), (ROWS * COLS * bits as usize) / 8);
        assert_eq!(ct.num_groups(), ROWS * COLS / GROUP_LEN);
        check_golden(&format!("fixed_int{bits}"), &serialize_fixed(&ct));
        // The parallel engine must serialize identically (bit-identity
        // is the format's other invariant).
        let pt = QuantEngine::with_threads(4)
            .quantize_seeded(&h, GROUP_LEN, bits, &BinSpec::Uniform, QUANT_SEED)
            .unwrap();
        assert_eq!(serialize_fixed(&ct), serialize_fixed(&pt), "bits={bits}");
    }
}

#[test]
fn golden_planned_one_bit() {
    let h = fixture_input();
    let plan = BitPlan::uniform(1, ROWS * COLS / GROUP_LEN, GROUP_LEN).unwrap();
    let pt = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, QUANT_SEED)
        .unwrap();
    assert_eq!(pt.packed.len(), ROWS * COLS / 8);
    check_golden("planned_int1", &serialize_planned(&pt));
    let par = QuantEngine::with_threads(4)
        .quantize_planned_seeded(&h, &plan, QUANT_SEED)
        .unwrap();
    assert_eq!(serialize_planned(&pt), serialize_planned(&par));
}

#[test]
fn golden_planned_heterogeneous() {
    let h = fixture_input();
    let plan = hetero_plan();
    let pt = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, QUANT_SEED)
        .unwrap();
    // 3 cycles of (1+2+4+8)-bit blocks of 32 scalars = 3*(4+8+16+32) B.
    assert_eq!(pt.packed.len(), 180);
    check_golden("planned_hetero", &serialize_planned(&pt));
    let par = QuantEngine::with_threads(8)
        .quantize_planned_seeded(&h, &plan, QUANT_SEED)
        .unwrap();
    assert_eq!(serialize_planned(&pt), serialize_planned(&par));
}

#[test]
fn golden_fixtures_hold_under_every_forced_isa() {
    // The runtime-dispatched kernels must not perturb the frozen layout:
    // each available ISA tier, forced end to end through the engine,
    // serializes to the *same committed fixtures* (no re-bless) and
    // dequantizes bit-identically to the serial default path.
    let h = fixture_input();
    let baseline = QuantEngine::serial();
    for isa in CodecIsa::available() {
        let engine = QuantEngine::serial().with_codec_isa(isa).unwrap();
        for bits in [2u32, 4, 8] {
            let ct = engine
                .quantize_seeded(&h, GROUP_LEN, bits, &BinSpec::Uniform, QUANT_SEED)
                .unwrap();
            check_golden(&format!("fixed_int{bits}"), &serialize_fixed(&ct));
            let want = baseline
                .quantize_seeded(&h, GROUP_LEN, bits, &BinSpec::Uniform, QUANT_SEED)
                .unwrap();
            assert_eq!(
                engine.dequantize(&ct).unwrap().as_slice(),
                baseline.dequantize(&want).unwrap().as_slice(),
                "dequantize isa={isa} bits={bits}"
            );
        }
        let plan = hetero_plan();
        let pt = engine.quantize_planned_seeded(&h, &plan, QUANT_SEED).unwrap();
        check_golden("planned_hetero", &serialize_planned(&pt));
        let want = baseline.quantize_planned_seeded(&h, &plan, QUANT_SEED).unwrap();
        assert_eq!(
            engine.dequantize_planned(&pt).unwrap().as_slice(),
            baseline.dequantize_planned(&want).unwrap().as_slice(),
            "planned dequantize isa={isa}"
        );
    }
}

#[test]
fn golden_fixtures_dequantize_within_width_bound() {
    // The frozen bytes must stay *semantically* valid too: round-trip
    // error bounded by each block's own step size.
    let h = fixture_input();
    let plan = hetero_plan();
    let pt = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, QUANT_SEED)
        .unwrap();
    let d = pt.dequantize().unwrap();
    for (idx, (&orig, &deq)) in h.as_slice().iter().zip(d.as_slice()).enumerate() {
        let g = idx / GROUP_LEN;
        let b = ((1u32 << plan.bit(g)) - 1) as f32;
        let width = pt.ranges[g] / b;
        assert!(
            (orig - deq).abs() <= width * 1.0001,
            "idx={idx}: |{orig} - {deq}| > {width}"
        );
    }
}
