//! Checkpoint round-trip coverage (ISSUE 3): save → load → continue
//! training must reproduce the **bit-identical** loss trajectory of an
//! uninterrupted run, for both fixed-width and adaptive-allocation
//! configurations (the V2 state format persists the active BitPlans so
//! the resumed allocator stays on the original schedule).

use iexact::checkpoint::{load_state, save_state};
use iexact::config::{
    AllocStrategy, AllocationConfig, DatasetSpec, QuantConfig, TrainConfig,
};
use iexact::pipeline::train_span;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("iexact_resume_{name}_{}", std::process::id()))
}

fn cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs,
        lr: 0.02,
        weight_decay: 0.0,
        seeds: vec![0],
        eval_every: 2,
        ..TrainConfig::default()
    }
}

#[test]
fn resume_reproduces_uninterrupted_trajectory() {
    let ds = DatasetSpec::tiny().generate(1);
    let q = QuantConfig::int2_blockwise(8);
    // Uninterrupted reference: 12 epochs straight through.
    let (whole, _) = train_span(&ds, &q, &cfg(12), 5, None).unwrap();

    // Interrupted run: 7 epochs, save, load, continue to 12.
    let (head, state) = train_span(&ds, &q, &cfg(7), 5, None).unwrap();
    assert_eq!(state.epoch, 7);
    let path = tmp("fixed");
    save_state(&state, &path).unwrap();
    let restored = load_state(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let (tail, done) = train_span(&ds, &q, &cfg(12), 5, Some(restored)).unwrap();
    assert_eq!(done.epoch, 12);

    // The final epoch's training loss is bit-identical...
    assert_eq!(whole.final_train_loss, tail.final_train_loss);
    // ...and so is every curve point the two runs share. The whole run
    // evaluates at epochs 0,2,4,...,11; head covers [0,7), tail [7,12).
    for (j, e) in head.curve.epochs.iter().enumerate() {
        let i = whole
            .curve
            .epochs
            .iter()
            .position(|we| we == e)
            .unwrap_or_else(|| panic!("epoch {e} missing from whole-run curve"));
        assert_eq!(whole.curve.train_loss[i], head.curve.train_loss[j], "head epoch {e}");
        assert_eq!(whole.curve.val_loss[i], head.curve.val_loss[j], "head epoch {e}");
    }
    for (j, e) in tail.curve.epochs.iter().enumerate() {
        let i = whole
            .curve
            .epochs
            .iter()
            .position(|we| we == e)
            .unwrap_or_else(|| panic!("epoch {e} missing from whole-run curve"));
        assert_eq!(whole.curve.train_loss[i], tail.curve.train_loss[j], "tail epoch {e}");
        assert_eq!(whole.curve.val_loss[i], tail.curve.val_loss[j], "tail epoch {e}");
    }
}

#[test]
fn resume_preserves_adaptive_allocation_schedule() {
    // The adaptive allocator re-solves plans at epochs 0, 4, 8, ... from
    // the model *at that epoch*. Resuming at epoch 6 must reuse the
    // epoch-4 plans from the checkpoint (re-deriving them would see the
    // epoch-6 model and fork the trajectory).
    let ds = DatasetSpec::tiny().generate(2);
    let q = QuantConfig::int2_blockwise(8);
    let alloc = AllocationConfig {
        strategy: AllocStrategy::Greedy,
        budget_bits: 2.0,
        realloc_interval_epochs: 4,
        min_bits: 1,
        max_bits: 8,
    };
    let mut c10 = cfg(10);
    c10.allocation = alloc.clone();
    let (whole, _) = train_span(&ds, &q, &c10, 3, None).unwrap();

    let mut c6 = cfg(6);
    c6.allocation = alloc;
    let (_, state) = train_span(&ds, &q, &c6, 3, None).unwrap();
    assert!(
        state.plans.is_some(),
        "adaptive run must checkpoint its active plans"
    );
    let path = tmp("adaptive");
    save_state(&state, &path).unwrap();
    let restored = load_state(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(restored.plans, state.plans);
    let (tail, _) = train_span(&ds, &q, &c10, 3, Some(restored)).unwrap();
    assert_eq!(whole.final_train_loss, tail.final_train_loss);
}

#[test]
fn resume_rejects_mismatched_config() {
    let ds = DatasetSpec::tiny().generate(3);
    let q = QuantConfig::int2_blockwise(8);
    let (_, state) = train_span(&ds, &q, &cfg(2), 1, None).unwrap();
    // Wrong depth.
    let mut deeper = cfg(4);
    deeper.num_layers = 4;
    assert!(train_span(&ds, &q, &deeper, 1, Some(state.clone())).is_err());
    // Wrong width: same arch and depth, different hidden_dim — weight
    // shapes no longer match what the config/dataset would initialize.
    let mut wider = cfg(4);
    wider.hidden_dim = 64;
    assert!(train_span(&ds, &q, &wider, 1, Some(state.clone())).is_err());
    // Beyond the horizon.
    assert!(train_span(&ds, &q, &cfg(1), 1, Some(state)).is_err());
}

#[test]
fn resume_rejects_mismatched_allocation_regime() {
    let ds = DatasetSpec::tiny().generate(3);
    let q = QuantConfig::int2_blockwise(8);
    let adaptive = AllocationConfig {
        strategy: AllocStrategy::Greedy,
        budget_bits: 2.0,
        realloc_interval_epochs: 4,
        min_bits: 1,
        max_bits: 8,
    };

    // Adaptive checkpoint into a fixed-width config: the checkpointed
    // plans would silently execute under a config that promises fixed
    // width — rejected.
    let mut c3 = cfg(3);
    c3.allocation = adaptive.clone();
    let (_, adaptive_state) = train_span(&ds, &q, &c3, 1, None).unwrap();
    assert!(adaptive_state.plans.is_some());
    assert!(train_span(&ds, &q, &cfg(6), 1, Some(adaptive_state)).is_err());

    // Fixed checkpoint into an adaptive config off a realloc boundary
    // (epoch 3, interval 4): epochs until the next re-solve would run at
    // full width — rejected. At a boundary (epoch 4) it is a legitimate
    // upgrade: plans are solved immediately.
    let (_, fixed_state3) = train_span(&ds, &q, &cfg(3), 1, None).unwrap();
    let mut c8 = cfg(8);
    c8.allocation = adaptive;
    assert!(train_span(&ds, &q, &c8, 1, Some(fixed_state3)).is_err());
    let (_, fixed_state4) = train_span(&ds, &q, &cfg(4), 1, None).unwrap();
    let (_, done) = train_span(&ds, &q, &c8, 1, Some(fixed_state4)).unwrap();
    assert_eq!(done.epoch, 8);
    assert!(done.plans.is_some(), "upgraded run solves plans at epoch 4");
}
