//! Deterministic chaos suite for the fault-tolerant distributed
//! runtime (ISSUE 10).
//!
//! Every test drives `train_distributed` (or `_with`) against workers
//! armed with a seeded [`ChaosSchedule`] and holds the runtime to the
//! acceptance bar: under **every** fault schedule the run either
//! completes with final weights **bit-identical** to an undisturbed
//! run, or fails with a *named* error — and no test may hang (each is
//! watchdog-bounded). The hung-worker test additionally pins the
//! latency claim: a stalled-but-alive worker is declared dead within
//! the configured deadline budget, not waited out.

use iexact::checkpoint::state_to_bytes;
use iexact::config::{DatasetSpec, PartitionConfig, QuantConfig, TrainConfig};
use iexact::coordinator::dist::chaos::{ChaosSchedule, Fault};
use iexact::coordinator::dist::{
    run_worker, train_distributed, train_distributed_with, DistHooks, DistTrainOutcome,
    WorkerOptions,
};
use iexact::pipeline::{train_partitioned_span, PartitionTrainResult};
use std::net::TcpListener;
use std::time::Duration;

const DATASET_SEED: u64 = 1;
const SEED: u64 = 7;

fn spec() -> DatasetSpec {
    DatasetSpec::tiny()
}

fn base_cfg(k: usize, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 6,
        lr: 0.02,
        eval_every: 2,
        seeds: vec![SEED],
        partition: PartitionConfig {
            num_partitions: k,
            halo_hops: 1,
            cache_bits: 2,
            ..PartitionConfig::default()
        },
        ..TrainConfig::default()
    };
    cfg.distributed.workers = workers;
    cfg
}

/// Run `f` on its own thread and panic (failing the test) if it does
/// not finish within `secs` — the suite's no-hang guarantee. A timed
/// out closure's thread leaks, which is fine: the watchdog firing IS
/// the test failure.
fn watchdog<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("watchdog: test exceeded its deadline — the runtime hung")
}

/// Leader + in-process chaos-armed worker threads over real TCP.
/// Worker threads are detached, not joined: a chaos-killed or stalled
/// worker exits on its own once the leader's sockets close, and a
/// join here would re-introduce exactly the hang the suite forbids.
fn run_chaos(
    quant: &QuantConfig,
    cfg: &TrainConfig,
    opts: Vec<WorkerOptions>,
) -> iexact::Result<DistTrainOutcome> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    for (rank, o) in opts.into_iter().enumerate() {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let _ = run_worker(&addr, rank as u32, &o);
        });
    }
    train_distributed(&listener, &spec(), DATASET_SEED, quant, cfg, SEED, None)
}

fn reference(quant: &QuantConfig, k: usize) -> PartitionTrainResult {
    let ds = spec().generate(DATASET_SEED);
    train_partitioned_span(&ds, quant, &base_cfg(k, 0), SEED, None)
        .unwrap()
        .0
}

fn assert_weights_identical(a: &PartitionTrainResult, b: &PartitionTrainResult, what: &str) {
    assert_eq!(
        a.result.curve.train_loss, b.result.curve.train_loss,
        "{what}: train-loss curve diverged"
    );
    assert_eq!(
        a.result.test_accuracy, b.result.test_accuracy,
        "{what}: test accuracy diverged"
    );
    for (l, (wa, wb)) in a.model.weights.iter().zip(&b.model.weights).enumerate() {
        assert_eq!(
            wa.as_slice(),
            wb.as_slice(),
            "{what}: layer {l} weights diverged"
        );
    }
}

fn chaos_opts(schedule: &ChaosSchedule, workers: usize) -> Vec<WorkerOptions> {
    (0..workers)
        .map(|_| WorkerOptions {
            chaos: Some(schedule.clone()),
            ..Default::default()
        })
        .collect()
}

/// Each fault kind on a steady-state frame of worker 1: survivable
/// kinds (drop, delay, truncate) complete bit-identical to the
/// undisturbed reference; a bit-flip is a *confused* peer, which must
/// fail loudly as a named checksum error, never silently train on.
#[test]
fn every_fault_kind_completes_identical_or_fails_named() {
    watchdog(300, || {
        let quant = QuantConfig::int2_blockwise(4);
        let reference = reference(&quant, 4);
        for (spec_str, lethal) in [
            ("1:4:drop", true),
            ("1:4:delay:100", false),
            ("1:4:trunc", true),
        ] {
            let schedule = ChaosSchedule::parse(spec_str).unwrap();
            let out = run_chaos(&quant, &base_cfg(4, 2), chaos_opts(&schedule, 2)).unwrap();
            assert_weights_identical(&reference, &out.result, spec_str);
            if lethal {
                assert!(
                    out.faults.deaths >= 1,
                    "{spec_str}: the faulted worker was never declared dead"
                );
                assert!(
                    out.reassigned_partitions > 0,
                    "{spec_str}: no partitions were reassigned"
                );
            } else {
                assert_eq!(
                    out.faults.deaths, 0,
                    "{spec_str}: a merely slow worker was declared dead"
                );
            }
        }
        // Bit-flip: the frame checksum must catch it and the leader
        // must abort with a named protocol error.
        let schedule = ChaosSchedule::parse("1:4:flip").unwrap();
        let err = run_chaos(&quant, &base_cfg(4, 2), chaos_opts(&schedule, 2)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("checksum"), "{msg}");
    });
}

/// Seeded pseudo-random schedules over both workers: every outcome is
/// either bit-identical completion or a named error — nothing hangs,
/// nothing silently diverges.
#[test]
fn seeded_schedules_complete_identical_or_fail_named() {
    watchdog(600, || {
        let quant = QuantConfig::int2_blockwise(4);
        let reference = reference(&quant, 4);
        let kinds = [Fault::Drop, Fault::Delay { ms: 30 }, Fault::Truncate];
        for chaos_seed in 1..=4u64 {
            let schedule = ChaosSchedule::seeded(chaos_seed, 2, 3, 24, &kinds);
            assert!(!schedule.is_empty());
            match run_chaos(&quant, &base_cfg(4, 2), chaos_opts(&schedule, 2)) {
                Ok(out) => {
                    assert_weights_identical(
                        &reference,
                        &out.result,
                        &format!("chaos seed {chaos_seed}"),
                    );
                }
                Err(e) => {
                    // Only the all-dead exhaustion is an acceptable
                    // failure for these (non-corrupting) kinds, and it
                    // must be the named protocol error.
                    let msg = e.to_string();
                    assert!(
                        msg.contains("workers are dead"),
                        "chaos seed {chaos_seed}: unexpected failure: {msg}"
                    );
                }
            }
        }
    });
}

/// The latency acceptance bar: a hung-but-alive worker (stalls 8 s
/// mid-epoch) no longer stalls the epoch past the configured deadline
/// budget. With `io_timeout_ms = 150` and one retry, the leader must
/// declare it dead, reassign, and finish the whole run — bit-identical
/// — in a small multiple of the deadline, not the stall.
#[test]
fn hung_worker_is_declared_dead_within_the_deadline_budget() {
    watchdog(120, || {
        let quant = QuantConfig::int2_blockwise(4);
        let reference = reference(&quant, 4);
        let mut cfg = base_cfg(4, 2);
        cfg.fault_tolerance.io_timeout_ms = 150;
        cfg.fault_tolerance.max_retries = 1;
        cfg.fault_tolerance.backoff_base_ms = 10;
        cfg.fault_tolerance.backoff_cap_ms = 20;
        let opts = vec![
            WorkerOptions::default(),
            WorkerOptions {
                stall_after_steps: Some(1),
                stall_ms: 8_000,
                ..Default::default()
            },
        ];
        let t0 = std::time::Instant::now();
        let out = run_chaos(&quant, &cfg, opts).unwrap();
        let elapsed = t0.elapsed();
        assert!(
            out.faults.timeouts >= 1,
            "the stall never surfaced as a read deadline"
        );
        assert!(
            out.faults.deaths >= 1,
            "the hung worker was never declared dead"
        );
        assert!(
            out.reassigned_partitions > 0,
            "the hung worker's partitions were never reassigned"
        );
        assert!(
            elapsed < Duration::from_millis(6_000),
            "leader took {elapsed:?} — it waited out the 8 s stall instead of \
             cutting the worker loose at the deadline"
        );
        assert_weights_identical(&reference, &out.result, "hung worker");
    });
}

/// Chaos kill + elastic restart in one run: worker 1 is chaos-dropped
/// mid-epoch, the respawn hook brings up a clean `rejoin` replacement,
/// and the final state is still bit-identical to the undisturbed run.
#[test]
fn chaos_killed_worker_restarts_and_stays_bit_identical() {
    watchdog(120, || {
        let quant = QuantConfig::int2_blockwise(4);
        let ds = spec().generate(DATASET_SEED);
        let (reference, ref_state) =
            train_partitioned_span(&ds, &quant, &base_cfg(4, 0), SEED, None).unwrap();
        let cfg = base_cfg(4, 2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let schedule = ChaosSchedule::parse("1:6:drop").unwrap();
        for (rank, o) in chaos_opts(&schedule, 2).into_iter().enumerate() {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let _ = run_worker(&addr, rank as u32, &o);
            });
        }
        let out = {
            let hooks = DistHooks {
                respawn: Some(Box::new(|rank| {
                    let addr = addr.clone();
                    std::thread::spawn(move || {
                        let _ = run_worker(
                            &addr,
                            rank,
                            &WorkerOptions {
                                rejoin: true,
                                ..Default::default()
                            },
                        );
                    });
                    Ok(())
                })),
            };
            train_distributed_with(
                &listener,
                &spec(),
                DATASET_SEED,
                &quant,
                &cfg,
                SEED,
                None,
                hooks,
            )
            .unwrap()
        };
        assert!(out.faults.deaths >= 1, "the chaos drop was never noticed");
        assert!(
            out.faults.restarts >= 1,
            "the dead worker was never restarted"
        );
        assert_weights_identical(&reference, &out.result, "chaos + restart");
        assert_eq!(
            state_to_bytes(&ref_state),
            state_to_bytes(&out.state),
            "chaos + restart: checkpoint state bytes diverged"
        );
    });
}

/// The spec grammar round-trips through the env-var transport the CLI
/// leader uses to arm spawned worker processes.
#[test]
fn schedule_spec_round_trips() {
    let schedule = ChaosSchedule::parse("0:3:drop;1:5:delay:250;1:9:trunc;0:11:flip").unwrap();
    assert_eq!(schedule.len(), 4);
    let reparsed = ChaosSchedule::parse(&schedule.to_spec()).unwrap();
    assert_eq!(schedule, reparsed);
    // Seeded schedules round-trip too (the leader serializes one into
    // IEXACT_CHAOS for its children).
    let seeded = ChaosSchedule::seeded(9, 2, 4, 16, &[Fault::Drop, Fault::Delay { ms: 40 }]);
    assert_eq!(seeded, ChaosSchedule::parse(&seeded.to_spec()).unwrap());
}
