//! Property-based tests over the compression substrate, using the
//! in-crate prop harness (rust/src/util/prop.rs): randomized inputs with
//! shrinking, covering the paper's key invariants for *arbitrary*
//! shapes/values rather than hand-picked fixtures.

use iexact::quant::{
    pack_codes, quantize_grouped, stochastic_round, unpack_codes, BinSpec,
};
use iexact::rngs::Pcg64;
use iexact::rp::RandomProjection;
use iexact::stats::ClippedNormal;
use iexact::tensor::Matrix;
use iexact::util::prop::{self, Strategy};
use iexact::varmin::{optimal_boundaries, sr_variance};

#[test]
fn prop_pack_unpack_roundtrip() {
    struct Codes;
    impl Strategy for Codes {
        type Value = (u32, Vec<u8>);
        fn generate(&self, rng: &mut Pcg64) -> Self::Value {
            let bits = [2u32, 4, 8][rng.next_bounded(3) as usize];
            let n = rng.next_bounded(200) as usize;
            let max = 1u64 << bits;
            let codes = (0..n).map(|_| rng.next_bounded(max) as u8).collect();
            (bits, codes)
        }
    }
    prop::check("pack/unpack roundtrip", 300, Codes, |(bits, codes)| {
        let packed = pack_codes(codes, *bits).unwrap();
        unpack_codes(&packed, *bits, codes.len()).unwrap() == *codes
    });
}

#[test]
fn prop_quant_dequant_error_bounded() {
    // For any tensor and group size, |ĥ − h| ≤ group range / B.
    prop::check(
        "quant-dequant error bound",
        60,
        prop::pair(prop::vec_f32(8, 256, -10.0, 10.0), prop::usize_range(1, 64)),
        |(data, group)| {
            let n = data.len();
            let m = Matrix::from_vec(1, n, data.clone()).unwrap();
            let mut rng = Pcg64::new(7);
            let ct = quantize_grouped(&m, *group, 2, &BinSpec::Uniform, &mut rng).unwrap();
            let d = ct.dequantize().unwrap();
            data.iter().zip(d.as_slice()).enumerate().all(|(i, (&o, &q))| {
                let g = i / *group;
                (o - q).abs() <= ct.ranges[g] / 3.0 + 1e-5
            })
        },
    );
}

#[test]
fn prop_quant_metadata_bytes_exact() {
    // nbytes = ceil(n·bits/8) + 8·ceil(n/group) for every shape.
    prop::check(
        "compressed nbytes formula",
        100,
        prop::pair(prop::usize_range(1, 500), prop::usize_range(1, 100)),
        |(n, group)| {
            let mut rng = Pcg64::new(3);
            let m = Matrix::from_fn(1, *n, |_, _| rng.next_f32());
            let ct = quantize_grouped(&m, *group, 2, &BinSpec::Uniform, &mut rng).unwrap();
            ct.nbytes() == (n * 2).div_ceil(8) + 8 * n.div_ceil(*group)
        },
    );
}

#[test]
fn prop_sr_nonuniform_within_neighbours() {
    // SR always returns one of the two neighbouring boundary indices.
    struct Case;
    impl Strategy for Case {
        type Value = (f64, f64, f64);
        fn generate(&self, rng: &mut Pcg64) -> Self::Value {
            let a = 0.2 + rng.next_f64() * 1.2;
            let b = a + 0.1 + rng.next_f64() * (2.8 - a);
            let h = rng.next_f64() * 3.0;
            (a, b.min(2.95), h)
        }
    }
    prop::check("SR returns a neighbour", 500, Case, |(a, b, h)| {
        let bounds = [0.0, *a, *b, 3.0];
        let mut rng = Pcg64::new(11);
        let code = stochastic_round(*h, &bounds, &mut rng) as usize;
        // h must lie within [bounds[code-1], bounds[code+1]].
        let lo = if code == 0 { 0.0 } else { bounds[code - 1] };
        let hi = if code == 3 { 3.0 } else { bounds[code + 1] };
        (lo..=hi).contains(h)
    });
}

#[test]
fn prop_sr_variance_nonnegative_and_bounded() {
    // 0 ≤ Var ≤ δ²/4 with δ the containing bin width.
    struct Case;
    impl Strategy for Case {
        type Value = (f64, f64, f64);
        fn generate(&self, rng: &mut Pcg64) -> Self::Value {
            let a = 0.1 + rng.next_f64() * 1.3;
            let b = a + 0.05 + rng.next_f64() * (2.9 - a);
            (a, b.min(2.95), rng.next_f64() * 3.0)
        }
    }
    prop::check("SR variance bounds", 500, Case, |(a, b, h)| {
        let bounds = [0.0, *a, *b, 3.0];
        let v = sr_variance(*h, &bounds);
        let widths = [*a, b - a, 3.0 - b];
        let max_w = widths.iter().cloned().fold(0.0f64, f64::max);
        v >= -1e-12 && v <= max_w * max_w / 4.0 + 1e-12
    });
}

#[test]
fn prop_optimal_boundaries_always_beat_uniform() {
    prop::check(
        "VM optimum beats uniform bins",
        40,
        prop::usize_range(4, 600),
        |&d| {
            let cn = ClippedNormal::new(2, d).unwrap();
            let opt = optimal_boundaries(&cn).unwrap();
            opt.variance <= opt.uniform_variance && opt.reduction() >= 0.0
        },
    );
}

#[test]
fn prop_projection_shapes_and_scale() {
    prop::check(
        "RP matrix entries are ±1/sqrt(r)",
        60,
        prop::pair(prop::usize_range(2, 64), prop::usize_range(1, 32)),
        |(d, r)| {
            if r > d {
                return true; // constructor rejects; covered by unit tests
            }
            let mut rng = Pcg64::new(5);
            let rp = RandomProjection::new(*d, *r, &mut rng).unwrap();
            let s = 1.0 / (*r as f32).sqrt();
            rp.matrix().as_slice().iter().all(|&v| v == s || v == -s)
        },
    );
}

#[test]
fn prop_blockwise_never_larger_than_rowwise() {
    // For any projected matrix, block-wise with G ≥ R uses ≤ bytes of the
    // per-row scheme (the Table 1 memory claim, property form).
    prop::check(
        "blockwise ≤ rowwise bytes",
        60,
        prop::pair(prop::usize_range(2, 64), prop::usize_range(1, 8)),
        |(rows, ratio)| {
            let r_dim = 16;
            let mut rng = Pcg64::new(9);
            let m = Matrix::from_fn(*rows, r_dim, |_, _| rng.next_f32());
            let row = quantize_grouped(&m, r_dim, 2, &BinSpec::Uniform, &mut rng).unwrap();
            let blk =
                quantize_grouped(&m, ratio * r_dim, 2, &BinSpec::Uniform, &mut rng)
                    .unwrap();
            blk.nbytes() <= row.nbytes()
        },
    );
}
