//! Failure-injection tests: malformed artifacts, hostile inputs, and
//! degenerate numerical data must produce errors (or defined behaviour),
//! never panics.

use iexact::config::{DatasetSpec, QuantConfig, TrainConfig};
use iexact::quant::{quantize_grouped, BinSpec};
use iexact::rngs::Pcg64;
use iexact::runtime::Manifest;
use iexact::tensor::Matrix;

#[test]
fn corrupt_manifest_variants_error_cleanly() {
    for bad in [
        "",                                     // empty
        "not json at all",                      // garbage
        "{\"artifacts\": 3}",                   // wrong type
        "{\"artifacts\": [{\"name\": 1}]}",     // wrong field type
        "{\"artifacts\": [{}]}",                // missing fields
        "{\"artifacts\": [ {\"name\": \"x\", \"file\": \"f\", \"inputs\": [{\"name\": \"a\", \"shape\": [1]}], \"outputs\": []} ]}", // rank-1
    ] {
        assert!(Manifest::parse(bad).is_err(), "should reject: {bad:?}");
    }
}

#[test]
fn runtime_missing_artifact_file_errors() {
    // A manifest that references a file that does not exist on disk.
    let dir = std::env::temp_dir().join("iexact_missing_artifact_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "ghost", "file": "ghost.hlo.txt",
             "inputs": [], "outputs": []}]}"#,
    )
    .unwrap();
    let mut rt = iexact::runtime::Runtime::open(&dir).unwrap();
    assert!(rt.load("ghost").is_err());
    assert!(rt.load("never_registered").is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantizer_handles_degenerate_inputs_without_panic() {
    let mut rng = Pcg64::new(1);
    // All-identical values (zero range).
    let m = Matrix::from_fn(4, 8, |_, _| 1.25);
    let ct = quantize_grouped(&m, 8, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert_eq!(ct.dequantize().unwrap().as_slice(), m.as_slice());

    // Huge dynamic range.
    let m = Matrix::from_vec(1, 4, vec![-1e30, 0.0, 1e-30, 1e30]).unwrap();
    let ct = quantize_grouped(&m, 4, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert!(ct.dequantize().unwrap().as_slice().iter().all(|v| v.is_finite()));

    // Single element groups.
    let m = Matrix::from_vec(1, 3, vec![1.0, -2.0, 3.0]).unwrap();
    let ct = quantize_grouped(&m, 1, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert_eq!(ct.dequantize().unwrap().as_slice(), m.as_slice());
}

#[test]
fn nan_activations_do_not_panic() {
    let mut rng = Pcg64::new(2);
    let m = Matrix::from_vec(1, 4, vec![f32::NAN, 1.0, 2.0, 3.0]).unwrap();
    // NaN propagates (range is NaN) but must not panic or loop.
    let ct = quantize_grouped(&m, 4, 2, &BinSpec::Uniform, &mut rng).unwrap();
    let _ = ct.dequantize().unwrap();
}

#[test]
fn training_rejects_inconsistent_dataset() {
    let mut ds = DatasetSpec::tiny().generate(1);
    ds.labels[0] = 99; // out of range
    let cfg = TrainConfig {
        hidden_dim: 32,
        epochs: 2,
        seeds: vec![0],
        ..TrainConfig::default()
    };
    assert!(iexact::pipeline::train(&ds, &QuantConfig::fp32(), &cfg, 0).is_err());
}

#[test]
fn training_rejects_indivisible_hidden_dim() {
    let ds = DatasetSpec::tiny().generate(1);
    let cfg = TrainConfig {
        hidden_dim: 30, // not divisible by D/R = 8 — projection floors,
        epochs: 2,      // which the config layer rejects upfront
        seeds: vec![0],
        ..TrainConfig::default()
    };
    let exp = iexact::config::ExperimentConfig {
        dataset: DatasetSpec::tiny(),
        quant: QuantConfig::int2_exact(),
        train: cfg,
        dataset_seed: 1,
    };
    assert!(exp.validate().is_err());
    let _ = ds;
}

#[test]
fn toml_hostile_inputs() {
    use iexact::config::ExperimentConfig;
    for bad in [
        "[quant]\nmode = \"blockwise\"\ngroup_ratio = 0\n",
        "[quant]\nmode = \"exact\"\nbits = 16\n",
        "[train]\nepochs = 0\n",
        "[dataset]\nname = \"no-such-dataset\"\n",
    ] {
        assert!(ExperimentConfig::from_toml(bad).is_err(), "{bad}");
    }
}

#[test]
fn binspec_hostile_boundaries() {
    let m = Matrix::from_fn(2, 8, |_, c| c as f32);
    let mut rng = Pcg64::new(3);
    for bad in [
        BinSpec::NonUniform(vec![0.0, 2.0, 1.0, 3.0]),       // not increasing
        BinSpec::NonUniform(vec![0.5, 1.0, 2.0, 3.0]),       // doesn't start at 0
        BinSpec::NonUniform(vec![0.0, 1.0, 2.0]),            // wrong count
        BinSpec::NonUniform(vec![0.0, 1.0, 2.0, 2.5]),       // doesn't end at B
    ] {
        assert!(
            quantize_grouped(&m, 8, 2, &bad, &mut rng).is_err(),
            "{bad:?}"
        );
    }
}
