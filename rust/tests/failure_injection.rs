//! Failure-injection tests: malformed artifacts, hostile inputs, and
//! degenerate numerical data must produce errors (or defined behaviour),
//! never panics. The disk-fault section (ISSUE 6) injects truncated
//! chunks, corrupt manifests, vanishing spill dirs and ENOSPC-style
//! write failures into the out-of-core path: each must surface an error
//! naming the offending path, and checkpoints must stay resumable.

use iexact::alloc::BitPlan;
use iexact::config::{DatasetSpec, OutOfCoreConfig, PartitionConfig, QuantConfig, TrainConfig};
use iexact::engine::QuantEngine;
use iexact::memory::{ActivationCache, BufferPool};
use iexact::partition::{partition_dataset, PartitionStore};
use iexact::quant::{quantize_grouped, BinSpec};
use iexact::rngs::Pcg64;
use iexact::runtime::Manifest;
use iexact::tensor::Matrix;

#[test]
fn corrupt_manifest_variants_error_cleanly() {
    for bad in [
        "",                                     // empty
        "not json at all",                      // garbage
        "{\"artifacts\": 3}",                   // wrong type
        "{\"artifacts\": [{\"name\": 1}]}",     // wrong field type
        "{\"artifacts\": [{}]}",                // missing fields
        "{\"artifacts\": [ {\"name\": \"x\", \"file\": \"f\", \"inputs\": [{\"name\": \"a\", \"shape\": [1]}], \"outputs\": []} ]}", // rank-1
    ] {
        assert!(Manifest::parse(bad).is_err(), "should reject: {bad:?}");
    }
}

#[test]
fn runtime_missing_artifact_file_errors() {
    // A manifest that references a file that does not exist on disk.
    let dir = std::env::temp_dir().join("iexact_missing_artifact_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "ghost", "file": "ghost.hlo.txt",
             "inputs": [], "outputs": []}]}"#,
    )
    .unwrap();
    let mut rt = iexact::runtime::Runtime::open(&dir).unwrap();
    assert!(rt.load("ghost").is_err());
    assert!(rt.load("never_registered").is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantizer_handles_degenerate_inputs_without_panic() {
    let mut rng = Pcg64::new(1);
    // All-identical values (zero range).
    let m = Matrix::from_fn(4, 8, |_, _| 1.25);
    let ct = quantize_grouped(&m, 8, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert_eq!(ct.dequantize().unwrap().as_slice(), m.as_slice());

    // Huge dynamic range.
    let m = Matrix::from_vec(1, 4, vec![-1e30, 0.0, 1e-30, 1e30]).unwrap();
    let ct = quantize_grouped(&m, 4, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert!(ct.dequantize().unwrap().as_slice().iter().all(|v| v.is_finite()));

    // Single element groups.
    let m = Matrix::from_vec(1, 3, vec![1.0, -2.0, 3.0]).unwrap();
    let ct = quantize_grouped(&m, 1, 2, &BinSpec::Uniform, &mut rng).unwrap();
    assert_eq!(ct.dequantize().unwrap().as_slice(), m.as_slice());
}

#[test]
fn nan_activations_do_not_panic() {
    let mut rng = Pcg64::new(2);
    let m = Matrix::from_vec(1, 4, vec![f32::NAN, 1.0, 2.0, 3.0]).unwrap();
    // NaN propagates (range is NaN) but must not panic or loop.
    let ct = quantize_grouped(&m, 4, 2, &BinSpec::Uniform, &mut rng).unwrap();
    let _ = ct.dequantize().unwrap();
}

#[test]
fn training_rejects_inconsistent_dataset() {
    let mut ds = DatasetSpec::tiny().generate(1);
    ds.labels[0] = 99; // out of range
    let cfg = TrainConfig {
        hidden_dim: 32,
        epochs: 2,
        seeds: vec![0],
        ..TrainConfig::default()
    };
    assert!(iexact::pipeline::train(&ds, &QuantConfig::fp32(), &cfg, 0).is_err());
}

#[test]
fn training_rejects_indivisible_hidden_dim() {
    let ds = DatasetSpec::tiny().generate(1);
    let cfg = TrainConfig {
        hidden_dim: 30, // not divisible by D/R = 8 — projection floors,
        epochs: 2,      // which the config layer rejects upfront
        seeds: vec![0],
        ..TrainConfig::default()
    };
    let exp = iexact::config::ExperimentConfig {
        dataset: DatasetSpec::tiny(),
        quant: QuantConfig::int2_exact(),
        train: cfg,
        dataset_seed: 1,
    };
    assert!(exp.validate().is_err());
    let _ = ds;
}

#[test]
fn toml_hostile_inputs() {
    use iexact::config::ExperimentConfig;
    for bad in [
        "[quant]\nmode = \"blockwise\"\ngroup_ratio = 0\n",
        "[quant]\nmode = \"exact\"\nbits = 16\n",
        "[train]\nepochs = 0\n",
        "[dataset]\nname = \"no-such-dataset\"\n",
    ] {
        assert!(ExperimentConfig::from_toml(bad).is_err(), "{bad}");
    }
}

// ---------------------------------------------------------------------------
// Out-of-core disk faults (ISSUE 6)
// ---------------------------------------------------------------------------

fn fault_dir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("iexact_fault_{name}_{}", std::process::id()))
}

#[test]
fn truncated_chunk_file_is_rejected_by_name() {
    let dir = fault_dir("trunc_chunk");
    let ds = DatasetSpec::tiny().generate(1);
    let parts = partition_dataset(&ds, 4, 1).unwrap();
    PartitionStore::create(&parts, &dir).unwrap();

    let victim = dir.join("part-2.chunk");
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();

    // The manifest itself is intact, so open succeeds (chunks validate
    // lazily) — the damage must surface on the read, named.
    let store = PartitionStore::open(&dir).unwrap();
    let err = store.load_partition(2).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("part-2.chunk"), "error must name the chunk: {msg}");
    assert!(!msg.to_lowercase().contains("panic"));
    // Undamaged partitions still load — a single bad chunk does not
    // poison the store.
    assert!(store.load_partition(0).is_ok());
    assert!(store.load_partition(1).is_ok());

    // A zero-length chunk is rejected by name too.
    std::fs::write(&victim, []).unwrap();
    let msg = store.load_partition(2).unwrap_err().to_string();
    assert!(msg.contains("part-2.chunk"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_or_missing_manifest_is_rejected_by_name() {
    let dir = fault_dir("bad_manifest");
    let ds = DatasetSpec::tiny().generate(1);
    let parts = partition_dataset(&ds, 2, 1).unwrap();
    PartitionStore::create(&parts, &dir).unwrap();

    // Bit-flip in the body: checksum check fires, naming the file.
    let mpath = dir.join("manifest.bin");
    let mut bytes = std::fs::read(&mpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&mpath, &bytes).unwrap();
    let msg = PartitionStore::open(&dir).unwrap_err().to_string();
    assert!(msg.contains("manifest.bin"), "{msg}");
    assert!(msg.contains("checksum"), "{msg}");

    // Missing manifest (the crashed-writer signature: chunks present,
    // manifest absent) is also a named error, not a silent empty store.
    std::fs::remove_file(&mpath).unwrap();
    let msg = PartitionStore::open(&dir).unwrap_err().to_string();
    assert!(msg.contains("manifest.bin"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn spill_dir_vanishing_mid_epoch_surfaces_named_error() {
    let dir = fault_dir("vanish");
    let h = Matrix::from_fn(8, 16, |r, c| (r * 3 + c) as f32 * 0.25);
    let plan = BitPlan::uniform(2, 8, 16).unwrap();
    let engine = QuantEngine::serial();
    let mut pool = BufferPool::new();
    let mut cache = ActivationCache::with_spill(2, 5, &dir).unwrap();
    cache.park(0, &h, &plan, &engine, &mut pool).unwrap();
    cache.spill(0, &mut pool).unwrap();

    // The spill dir disappears between epochs (operator wipes /tmp, the
    // scratch volume unmounts…). Fetching the spilled slot must error
    // with the spill file's name — never panic, never return stale data.
    std::fs::remove_dir_all(&dir).unwrap();
    let err = cache.fetch(0, &engine, &mut pool).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("slot-0.spill"), "error must name the file: {msg}");

    // Training can continue in RAM: a fresh park into the slot works,
    // and the failed spill write (dir still gone) leaves it resident.
    cache.park(1, &h, &plan, &engine, &mut pool).unwrap();
    assert!(cache.spill(1, &mut pool).is_err());
    assert!(cache.resident_bytes() > 0, "failed spill must keep the slot");
    assert!(cache.fetch(1, &engine, &mut pool).unwrap().is_some());
}

#[test]
fn enospc_style_spill_target_fails_cleanly_and_checkpoint_survives() {
    // A regular file where the spill dir should go: every create/write
    // under it fails the way a full disk does — at the filesystem call.
    let blocker = fault_dir("enospc_blocker");
    std::fs::write(&blocker, b"not a directory").unwrap();
    let spill = blocker.join("spill");

    let ds = DatasetSpec::tiny().generate(1);
    let quant = QuantConfig::int2_blockwise(4);
    let cfg_ram = TrainConfig {
        hidden_dim: 32,
        num_layers: 2,
        epochs: 2,
        seeds: vec![0],
        partition: PartitionConfig {
            num_partitions: 2,
            halo_hops: 1,
            ..PartitionConfig::default()
        },
        ..TrainConfig::default()
    };

    // Healthy in-RAM run first; its checkpoint is the resume point.
    let good = iexact::pipeline::train_partitioned(&ds, &quant, &cfg_ram, 3).unwrap();
    let ckpt = fault_dir("enospc_ckpt");
    iexact::checkpoint::save(&good.model, &ckpt).unwrap();

    // The streaming run must fail with a named error, not panic.
    let mut cfg = cfg_ram.clone();
    cfg.out_of_core = OutOfCoreConfig {
        spill_dir: Some(spill.to_string_lossy().into_owned()),
        resident_budget_bytes: 0,
        prefetch_depth: 1,
    };
    let err = iexact::pipeline::train_partitioned(&ds, &quant, &cfg, 3).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("iexact_fault_enospc_blocker"),
        "error must name the unwritable path: {msg}"
    );

    // The pre-fault checkpoint is untouched and resumes bit-exactly.
    let resumed = iexact::checkpoint::load(&ckpt).unwrap();
    assert_eq!(resumed.weights.len(), good.model.weights.len());
    for (a, b) in resumed.weights.iter().zip(&good.model.weights) {
        assert_eq!(a.as_slice(), b.as_slice());
    }
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(&blocker).ok();
}

// ---------------------------------------------------------------------------
// Wire planned-tensor rejections (serving/distributed trust boundary)
// ---------------------------------------------------------------------------

#[test]
fn wire_planned_tensor_rejections_are_named() {
    let engine = QuantEngine::serial();
    let mut pool = BufferPool::new();
    let h = Matrix::from_fn(8, 16, |r, c| (r * 5 + c) as f32 * 0.5 - 3.0);
    let plan = BitPlan::uniform(2, 8, 16).unwrap();
    let wire = engine.pack_to_wire(&h, &plan, 7, &mut pool).unwrap();

    // The healthy body round-trips.
    let pt = engine.decode_from_wire(&wire, &mut pool).unwrap();
    assert_eq!(pt.shape, (8, 16));

    // Truncated packed body: the last codes are missing.
    let msg = engine
        .decode_from_wire(&wire[..wire.len() - 3], &mut pool)
        .unwrap_err()
        .to_string();
    assert!(msg.contains("wire planned tensor"), "{msg}");
    assert!(msg.contains("truncated"), "{msg}");

    // Any shorter prefix errors too — header cuts, mid-metadata cuts —
    // never panics, never returns a tensor.
    for cut in [0, 1, 7, 8, 31, 32, 33, wire.len() / 2, wire.len() - 1] {
        assert!(
            engine.decode_from_wire(&wire[..cut], &mut pool).is_err(),
            "cut={cut}"
        );
    }

    // Oversized body: bytes trailing the packed codes.
    let mut big = wire.clone();
    big.extend_from_slice(&[0u8; 5]);
    let msg = engine.decode_from_wire(&big, &mut pool).unwrap_err().to_string();
    assert!(msg.contains("wire planned tensor"), "{msg}");
    assert!(msg.contains("trailing bytes"), "{msg}");

    // Absurd declared packed length — rejected before any allocation.
    // Field offset: shape (2x u64) + group_len + num_blocks (u64 each),
    // bits bytes, zeros count + f32s, ranges count + f32s.
    let nb = plan.num_blocks();
    let packed_len_at = 8 * 4 + nb + 8 + 4 * nb + 8 + 4 * nb;
    let mut huge = wire.clone();
    huge[packed_len_at..packed_len_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    let msg = engine.decode_from_wire(&huge, &mut pool).unwrap_err().to_string();
    assert!(msg.contains("bad packed length"), "{msg}");

    // Shape/plan mismatch: the shape field claims 9 rows but the plan
    // still covers 8x16 scalars. Must be rejected at decode, not crash
    // a later dequantize.
    let mut bad_shape = wire.clone();
    bad_shape[0..8].copy_from_slice(&9u64.to_le_bytes());
    let msg = engine
        .decode_from_wire(&bad_shape, &mut pool)
        .unwrap_err()
        .to_string();
    assert!(msg.contains("wire planned tensor"), "{msg}");
    assert!(msg.contains("inconsistent body"), "{msg}");

    // Metadata/plan mismatch: a lying zeros count desyncs the body —
    // still a named wire error of some kind, never a panic.
    let zeros_count_at = 8 * 4 + nb;
    let mut bad_meta = wire.clone();
    bad_meta[zeros_count_at..zeros_count_at + 8].copy_from_slice(&7u64.to_le_bytes());
    let msg = engine
        .decode_from_wire(&bad_meta, &mut pool)
        .unwrap_err()
        .to_string();
    assert!(msg.contains("wire planned tensor"), "{msg}");
}

// ---------------------------------------------------------------------------
// Distributed + serving timeout paths (ISSUE 10)
// ---------------------------------------------------------------------------

/// Bounded-run guard: every timeout test must finish inside `secs`.
fn bounded<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(std::time::Duration::from_secs(secs))
        .expect("watchdog: timeout path hung instead of timing out")
}

/// A peer that connects and then goes silent must expire the leader's
/// handshake deadline as a named `Error::Timeout` — not block the run
/// forever (the pre-ISSUE-10 behaviour).
#[test]
fn leader_read_timeout_on_silent_worker_is_named() {
    bounded(60, || {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Silent "worker": connects, never says Hello, holds the socket.
        std::thread::spawn(move || {
            let _s = std::net::TcpStream::connect(addr).unwrap();
            std::thread::sleep(std::time::Duration::from_secs(30));
        });
        let mut cfg = TrainConfig {
            hidden_dim: 32,
            epochs: 2,
            seeds: vec![0],
            partition: PartitionConfig {
                num_partitions: 2,
                halo_hops: 1,
                ..PartitionConfig::default()
            },
            ..TrainConfig::default()
        };
        cfg.distributed.workers = 1;
        cfg.fault_tolerance.io_timeout_ms = 100; // handshake deadline = 10x
        let t0 = std::time::Instant::now();
        let err = iexact::coordinator::dist::train_distributed(
            &listener,
            &DatasetSpec::tiny(),
            1,
            &QuantConfig::int2_blockwise(4),
            &cfg,
            0,
            None,
        )
        .unwrap_err();
        assert!(matches!(err, iexact::Error::Timeout(_)), "{err}");
        assert!(err.to_string().contains("deadline"), "{err}");
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(20),
            "leader took {:?} to give up on a silent worker",
            t0.elapsed()
        );
    });
}

/// A worker whose leader accepts but never sends `Setup` must give up
/// at its own setup deadline with a named timeout, not hang.
#[test]
fn worker_setup_timeout_is_named() {
    bounded(60, || {
        // The "leader" listens but never accepts or speaks; the kernel
        // backlog completes the worker's connect anyway.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = iexact::coordinator::dist::WorkerOptions {
            setup_timeout_ms: 100,
            ..Default::default()
        };
        let err = iexact::coordinator::dist::run_worker(&addr, 0, &opts).unwrap_err();
        assert!(matches!(err, iexact::Error::Timeout(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("waiting for Setup"), "{msg}");
        drop(listener);
    });
}

/// Serve fixture: a tiny deterministic packed store behind a
/// `ServeEngine` (mirrors the serve_parity fixture, smaller).
fn serve_engine_fixture() -> iexact::serve::ServeEngine {
    use iexact::graph::CsrMatrix;
    let n = 16usize;
    let dim = 8usize;
    let mut edges: Vec<(usize, usize, f32)> = Vec::new();
    for v in 0..n {
        edges.push((v, v, 0.5));
        edges.push((v, (v * 3 + 1) % n, 0.25));
    }
    let adj = CsrMatrix::from_edges(n, &edges).unwrap();
    let emb = Matrix::from_fn(n, dim, |r, c| ((r * 13 + c * 5) % 41) as f32 * 0.3 - 4.1);
    let engine = QuantEngine::serial();
    let store =
        iexact::serve::EmbeddingStore::from_embeddings(emb, adj, &engine, 4, 4, 0x5e72_e001)
            .unwrap();
    iexact::serve::ServeEngine::new(store, engine)
}

/// A client that connects and stalls past `read_timeout_ms` is
/// disconnected (its handler thread freed) and counted in
/// `timed_out_connections` — visible over the wire and in the final
/// join stats.
#[test]
fn serve_stalled_client_is_disconnected_and_counted() {
    bounded(60, || {
        use std::io::Read;
        let cfg = iexact::config::ServeConfig {
            read_timeout_ms: 100,
            ..iexact::config::ServeConfig::default()
        };
        let handle = iexact::serve::ServerHandle::start(serve_engine_fixture(), &cfg).unwrap();
        let addr = handle.addr();

        // The stalled client: connects, sends nothing. The server must
        // hang up on it (we observe EOF) instead of waiting forever.
        let mut stalled = std::net::TcpStream::connect(addr).unwrap();
        let mut sink = Vec::new();
        let n = stalled.read_to_end(&mut sink).unwrap();
        assert_eq!(n, 0, "server should close a stalled connection");

        // A healthy client sees the counter over the wire.
        let mut client = iexact::serve::ServeClient::connect(&addr).unwrap();
        let stats = client.stats().unwrap();
        assert!(
            stats.timed_out_connections >= 1,
            "stall was not counted: {stats:?}"
        );
        client.shutdown().unwrap();
        drop(client);
        let (stats, _) = handle.join().unwrap();
        assert!(stats.timed_out_connections >= 1);
    });
}

/// Above `max_connections`, new connections are shed with a named
/// error reply instead of queueing unboundedly, and the shed is
/// counted.
#[test]
fn serve_sheds_connections_over_the_cap_with_named_error() {
    bounded(60, || {
        let cfg = iexact::config::ServeConfig {
            max_connections: 1,
            ..iexact::config::ServeConfig::default()
        };
        let handle = iexact::serve::ServerHandle::start(serve_engine_fixture(), &cfg).unwrap();
        let addr = handle.addr();

        let mut holder = iexact::serve::ServeClient::connect(&addr).unwrap();
        // First query proves the holder's handler is up (active == 1).
        holder.embed(&[0, 1]).unwrap();

        // Second connection: shed with a named error.
        let mut shed = iexact::serve::ServeClient::connect(&addr).unwrap();
        let msg = shed.stats().unwrap_err().to_string();
        assert!(msg.contains("max_connections"), "{msg}");
        assert!(msg.contains("shed"), "{msg}");
        drop(shed);

        // The holder's connection still works and sees the count.
        let stats = holder.stats().unwrap();
        assert!(stats.shed_connections >= 1, "{stats:?}");
        holder.shutdown().unwrap();
        drop(holder);
        let (stats, _) = handle.join().unwrap();
        assert!(stats.shed_connections >= 1);
    });
}

/// A dispatcher panic mid-batch is contained: the panicking batch's
/// queries get a named error, the engine keeps serving, and shutdown
/// still drains cleanly.
#[test]
fn serve_dispatcher_panic_is_contained_and_named() {
    bounded(60, || {
        let cfg = iexact::config::ServeConfig::default();
        let mut engine = serve_engine_fixture();
        engine.inject_panic_after(1);
        let handle = iexact::serve::ServerHandle::start(engine, &cfg).unwrap();
        let addr = handle.addr();

        let mut client = iexact::serve::ServeClient::connect(&addr).unwrap();
        let msg = client.embed(&[0, 1]).unwrap_err().to_string();
        assert!(msg.contains("dispatcher panicked"), "{msg}");
        // The engine survives the contained panic and keeps answering.
        let rows = client.embed(&[2, 3]).unwrap();
        assert_eq!(rows.rows(), 2);
        client.shutdown().unwrap();
        drop(client);
        let (stats, _) = handle.join().unwrap();
        assert!(stats.queries >= 2);
    });
}

#[test]
fn binspec_hostile_boundaries() {
    let m = Matrix::from_fn(2, 8, |_, c| c as f32);
    let mut rng = Pcg64::new(3);
    for bad in [
        BinSpec::NonUniform(vec![0.0, 2.0, 1.0, 3.0]),       // not increasing
        BinSpec::NonUniform(vec![0.5, 1.0, 2.0, 3.0]),       // doesn't start at 0
        BinSpec::NonUniform(vec![0.0, 1.0, 2.0]),            // wrong count
        BinSpec::NonUniform(vec![0.0, 1.0, 2.0, 2.5]),       // doesn't end at B
    ] {
        assert!(
            quantize_grouped(&m, 8, 2, &bad, &mut rng).is_err(),
            "{bad:?}"
        );
    }
}
