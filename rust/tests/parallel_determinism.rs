//! Parallel-vs-serial determinism contract for the quantization engine
//! (ISSUE 1 acceptance criterion): for the same seed, quantize and
//! dequantize must produce **bit-identical** packed buffers, metadata and
//! dequantized matrices at 1, 2 and 8 threads, across INT2/INT4/INT8 and
//! both bin layouts — threading is a speed knob, never a results knob.
//!
//! ISSUE 2 extends the contract to heterogeneous `BitPlan`s: per-block
//! RNG streams are keyed by block index alone, so adaptive bit widths
//! preserve bit-identity at every thread count too.

use iexact::alloc::{BitAllocator, BitPlan, BlockStats};
use iexact::engine::QuantEngine;
use iexact::quant::{quantize_grouped, quantize_grouped_seeded, BinSpec, BlockwiseQuantizer};
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;

fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 8.0 - 4.0)
}

#[test]
fn packed_buffers_bit_identical_across_thread_counts() {
    // Large enough that every thread count actually fans out: 512 rows x
    // 64 cols = 32768 scalars; G = 64 -> 512 blocks.
    let h = sample_matrix(512, 64, 1);
    for bits in [2u32, 4, 8] {
        let reference = QuantEngine::serial()
            .quantize_seeded(&h, 64, bits, &BinSpec::Uniform, 0xfeed)
            .unwrap();
        for threads in [1usize, 2, 8] {
            let ct = QuantEngine::with_threads(threads)
                .quantize_seeded(&h, 64, bits, &BinSpec::Uniform, 0xfeed)
                .unwrap();
            assert_eq!(ct.packed, reference.packed, "bits={bits} threads={threads}");
            assert_eq!(ct.zeros, reference.zeros, "bits={bits} threads={threads}");
            assert_eq!(ct.ranges, reference.ranges, "bits={bits} threads={threads}");
            assert_eq!(ct.nbytes(), reference.nbytes());
        }
    }
}

#[test]
fn dequantized_matrices_bit_identical_across_thread_counts() {
    let h = sample_matrix(256, 32, 2);
    for bits in [2u32, 4, 8] {
        let ct = QuantEngine::serial()
            .quantize_seeded(&h, 32, bits, &BinSpec::Uniform, 7)
            .unwrap();
        let reference = QuantEngine::serial().dequantize(&ct).unwrap();
        for threads in [1usize, 2, 8] {
            let d = QuantEngine::with_threads(threads).dequantize(&ct).unwrap();
            assert_eq!(
                d.as_slice(),
                reference.as_slice(),
                "bits={bits} threads={threads}"
            );
        }
    }
}

#[test]
fn vm_bins_bit_identical_across_thread_counts() {
    let h = sample_matrix(128, 32, 3);
    let bins = BinSpec::int2_vm(1.1, 1.9).unwrap();
    let reference = QuantEngine::serial()
        .quantize_seeded(&h, 32, 2, &bins, 11)
        .unwrap();
    for threads in [2usize, 8] {
        let ct = QuantEngine::with_threads(threads)
            .quantize_seeded(&h, 32, 2, &bins, 11)
            .unwrap();
        assert_eq!(ct.packed, reference.packed, "threads={threads}");
        let a = reference.dequantize().unwrap();
        let b = QuantEngine::with_threads(threads).dequantize(&ct).unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "threads={threads}");
    }
}

#[test]
fn ragged_group_sizes_bit_identical() {
    // Group lengths that do not divide the scalar count exercise the
    // partial trailing block on every shard boundary.
    let h = sample_matrix(33, 37, 4); // 1221 scalars
    for group in [5usize, 7, 100, 1221, 5000] {
        let reference = QuantEngine::serial()
            .quantize_seeded(&h, group, 2, &BinSpec::Uniform, 21)
            .unwrap();
        for threads in [2usize, 8] {
            let ct = QuantEngine::with_threads(threads)
                .quantize_seeded(&h, group, 2, &BinSpec::Uniform, 21)
                .unwrap();
            assert_eq!(ct.packed, reference.packed, "G={group} threads={threads}");
            assert_eq!(ct.zeros, reference.zeros, "G={group} threads={threads}");
        }
    }
}

#[test]
fn rng_entry_points_agree() {
    // quantize_grouped (rng draw) == quantize_grouped_seeded (explicit
    // seed) == engine.quantize: the rng advances by exactly one u64.
    let h = sample_matrix(64, 16, 5);
    let mut rng = Pcg64::new(99);
    let seed = {
        let mut probe = Pcg64::new(99);
        probe.next_u64()
    };
    let via_rng = quantize_grouped(&h, 16, 2, &BinSpec::Uniform, &mut rng).unwrap();
    let via_seed = quantize_grouped_seeded(&h, 16, 2, &BinSpec::Uniform, seed).unwrap();
    assert_eq!(via_rng.packed, via_seed.packed);

    let mut rng2 = Pcg64::new(99);
    let q = BlockwiseQuantizer::new(2, 16);
    let via_engine = q
        .quantize_on(&QuantEngine::with_threads(4), &h, &mut rng2)
        .unwrap();
    assert_eq!(via_rng.packed, via_engine.packed);
    // Both callers' generators are advanced identically.
    assert_eq!(rng.next_u64(), rng2.next_u64());
}

#[test]
fn heterogeneous_plan_bit_identical_across_thread_counts() {
    // A mixed-width plan (all four rungs present) quantizes and
    // dequantizes bit-identically at 1, 2 and 8 threads.
    let h = sample_matrix(512, 64, 7); // 32768 scalars, 512 blocks of 64
    let mut rng = Pcg64::new(8);
    let bits: Vec<u8> = (0..512)
        .map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize])
        .collect();
    let plan = BitPlan::new(bits, 64).unwrap();
    let reference = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, 0xfeed)
        .unwrap();
    let ref_deq = QuantEngine::serial().dequantize_planned(&reference).unwrap();
    for threads in [1usize, 2, 8] {
        let pt = QuantEngine::with_threads(threads)
            .quantize_planned_seeded(&h, &plan, 0xfeed)
            .unwrap();
        assert_eq!(pt.packed, reference.packed, "threads={threads}");
        assert_eq!(pt.zeros, reference.zeros, "threads={threads}");
        assert_eq!(pt.ranges, reference.ranges, "threads={threads}");
        let deq = QuantEngine::with_threads(threads)
            .dequantize_planned(&pt)
            .unwrap();
        assert_eq!(deq.as_slice(), ref_deq.as_slice(), "threads={threads}");
    }
}

#[test]
fn allocator_solved_plan_bit_identical_across_thread_counts() {
    // End-to-end with a plan the greedy allocator actually produces from
    // measured statistics (not a synthetic width pattern).
    let h = sample_matrix(256, 64, 9);
    let mut stats = BlockStats::measure(&h, 128).unwrap();
    stats.model_d = 64;
    let plan = BitAllocator::new(2.0, 1, 8)
        .unwrap()
        .allocate(&stats)
        .unwrap();
    let reference = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, 42)
        .unwrap();
    for threads in [2usize, 8] {
        let pt = QuantEngine::with_threads(threads)
            .quantize_planned_seeded(&h, &plan, 42)
            .unwrap();
        assert_eq!(pt.packed, reference.packed, "threads={threads}");
        assert_eq!(pt.zeros, reference.zeros, "threads={threads}");
    }
}

#[test]
fn quantizer_determinism_same_seed_same_bits() {
    // Same seed => same result; different seed => different SR draws.
    let h = sample_matrix(128, 64, 6);
    let q = BlockwiseQuantizer::new(2, 128);
    let mut r1 = Pcg64::new(42);
    let mut r2 = Pcg64::new(42);
    let mut r3 = Pcg64::new(43);
    let a = q.quantize(&h, &mut r1).unwrap();
    let b = q.quantize(&h, &mut r2).unwrap();
    let c = q.quantize(&h, &mut r3).unwrap();
    assert_eq!(a.packed, b.packed);
    assert_ne!(a.packed, c.packed);
}
