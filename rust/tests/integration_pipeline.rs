//! Cross-module integration tests on the native pipeline: dataset
//! generation → compressed training → metrics → memory model, plus the
//! paper's qualitative claims at test scale.

use iexact::config::{DatasetSpec, QuantConfig, TrainConfig};
use iexact::coordinator::{run_native_on, table1_configs};
use iexact::memory::MemoryModel;
use iexact::pipeline::train;

fn cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        arch: iexact::config::Arch::Gcn,
        hidden_dim: 32,
        num_layers: 3,
        epochs,
        lr: 0.02,
        weight_decay: 0.0,
        seeds: vec![0],
        eval_every: 5,
        ..TrainConfig::default()
    }
}

#[test]
fn all_table1_configs_train_on_tiny() {
    let ds = DatasetSpec::tiny().generate(1);
    for quant in table1_configs(&[2, 8, 64]) {
        let res = train(&ds, &quant, &cfg(15), 0)
            .unwrap_or_else(|e| panic!("{} failed: {e}", quant.label()));
        assert!(
            res.test_accuracy > 0.4,
            "{}: accuracy {}",
            quant.label(),
            res.test_accuracy
        );
    }
}

#[test]
fn accuracy_parity_between_fp32_and_int2() {
    // The paper's headline: INT2 compression costs ~no accuracy. At test
    // scale we allow a 12-point band (tiny graphs are noisier than OGB).
    let ds = DatasetSpec::tiny().generate(7);
    let c = cfg(30);
    let fp32 = train(&ds, &QuantConfig::fp32(), &c, 0).unwrap();
    let int2 = train(&ds, &QuantConfig::int2_blockwise(16), &c, 0).unwrap();
    assert!(
        (fp32.test_accuracy - int2.test_accuracy).abs() < 0.12,
        "fp32 {} vs int2 {}",
        fp32.test_accuracy,
        int2.test_accuracy
    );
}

#[test]
fn memory_model_matches_measured_stash() {
    // The analytic model (Table 1's M column) must agree with the actual
    // bytes the pipeline stashes, per layer composition.
    let ds = DatasetSpec::tiny().generate(3);
    let c = cfg(3);
    for quant in [
        QuantConfig::int2_exact(),
        QuantConfig::int2_blockwise(8),
        QuantConfig::int2_blockwise(64),
    ] {
        let res = train(&ds, &quant, &c, 0).unwrap();
        let model = MemoryModel::new(
            ds.num_nodes(),
            ds.num_features(),
            c.hidden_dim,
            c.num_layers,
        );
        let analytic = model.breakdown(&quant).unwrap();
        // The analytic model books a 1-bit sign pattern for every layer;
        // the final (classifier) layer has no ReLU, so the pipeline stashes
        // exactly that much less.
        let last_sign_bytes = (ds.num_nodes() * c.hidden_dim).div_ceil(8);
        let expected = analytic.total - last_sign_bytes;
        assert_eq!(
            res.stash_bytes,
            expected,
            "{}: measured {} != analytic-adjusted {}",
            quant.label(),
            res.stash_bytes,
            expected
        );
    }
}

#[test]
fn memory_ordering_matches_paper() {
    let model = MemoryModel::new(2048, 128, 128, 3);
    let fp32 = model.total_mb(&QuantConfig::fp32()).unwrap();
    let exact = model.total_mb(&QuantConfig::int2_exact()).unwrap();
    let mut last = exact;
    for g in [2, 4, 8, 16, 32, 64] {
        let mb = model.total_mb(&QuantConfig::int2_blockwise(g)).unwrap();
        assert!(mb < last, "G/R={g} must shrink memory");
        last = mb;
    }
    // >95% reduction vs FP32 (paper: ~97%).
    assert!(last < fp32 * 0.05);
}

#[test]
fn sweep_shares_dataset_across_configs() {
    let ds = DatasetSpec::tiny().generate(5);
    let c = cfg(8);
    let a = run_native_on(&ds, &QuantConfig::int2_exact(), &c).unwrap();
    let b = run_native_on(&ds, &QuantConfig::int2_blockwise(8), &c).unwrap();
    assert_eq!(a.summary.dataset, b.summary.dataset);
    assert!(a.summary.memory_mb > b.summary.memory_mb);
}

#[test]
fn toml_config_end_to_end() {
    let toml = r#"
[dataset]
name = "tiny"
seed = 5

[quant]
mode = "blockwise"
bits = 2
proj_ratio = 8
group_ratio = 8

[train]
hidden_dim = 32
epochs = 10
seeds = [0]
"#;
    let cfg = iexact::config::ExperimentConfig::from_toml(toml).unwrap();
    let out = iexact::coordinator::run_native(&cfg).unwrap();
    assert!(out.summary.epochs_per_sec > 0.0);
}
