//! Serving parity: every reply that leaves the compressed-embedding
//! query engine must be **bit-identical** to a full offline
//! `dequantize_planned` of the same packed store — through the naive
//! per-query path, the shared-tile batch path, the TCP wire, every
//! forced codec ISA, and after a serve-time transcode — while the
//! serving `BufferPool` proves the dense matrix was never rebuilt
//! (`max_float_take` stays at tile scale).
//!
//! The fixture is fully deterministic: synthetic embeddings and a
//! hand-built adjacency whose queried nodes (`0..QUERY_LIMIT`) only
//! ever reference neighbors below `QUERY_LIMIT`, so the last blocks of
//! the store are provably untouched by every batch — the shared tile
//! arena can never legitimately reach dense size.

use iexact::config::{ParallelismConfig, ServeConfig};
use iexact::engine::QuantEngine;
use iexact::graph::CsrMatrix;
use iexact::memory::BufferPool;
use iexact::quant::CodecIsa;
use iexact::serve::{BatchQueue, EmbeddingStore, Query, ServeClient, ServeEngine, ServerHandle};
use iexact::tensor::Matrix;

const N: usize = 64;
const DIM: usize = 16;
const ROWS_PER_BLOCK: usize = 4;
/// Queries only touch nodes below this; the adjacency keeps their
/// neighborhoods below it too, so blocks >= QUERY_LIMIT/ROWS_PER_BLOCK
/// are never decoded.
const QUERY_LIMIT: usize = 56;
const SEED: u64 = 0x5e72_e001;

fn adjacency() -> CsrMatrix {
    let mut edges: Vec<(usize, usize, f32)> = Vec::new();
    for v in 0..N {
        edges.push((v, v, 0.5));
    }
    for v in 0..QUERY_LIMIT {
        edges.push((v, (3 * v + 1) % QUERY_LIMIT, 0.25));
        edges.push((v, (7 * v + 5) % QUERY_LIMIT, 1.5));
    }
    CsrMatrix::from_edges(N, &edges).unwrap()
}

fn embeddings() -> Matrix {
    Matrix::from_fn(N, DIM, |r, c| ((r * 31 + c * 7) % 97) as f32 * 0.21 - 9.3)
}

fn store_fixture(engine: &QuantEngine, bits: u32) -> (EmbeddingStore, CsrMatrix) {
    let adj = adjacency();
    let store = EmbeddingStore::from_embeddings(
        embeddings(),
        adj.clone(),
        engine,
        bits,
        ROWS_PER_BLOCK,
        SEED,
    )
    .unwrap();
    (store, adj)
}

fn mixed_queries() -> Vec<Query> {
    let pick = |mul: usize, add: usize, len: usize| -> Vec<usize> {
        (0..len).map(|i| (i * mul + add) % QUERY_LIMIT).collect()
    };
    vec![
        Query::Embed(pick(7, 0, 5)),
        Query::Score(pick(13, 3, 4)),
        Query::Embed(vec![0, QUERY_LIMIT - 1, 0, QUERY_LIMIT / 2]),
        Query::Score(pick(5, 11, 6)),
        Query::Embed(pick(29, 1, 3)),
        Query::Score(vec![QUERY_LIMIT - 1]),
    ]
}

/// Assert `got` row `i` is bit-identical to `want` row `nodes[i]`.
fn assert_rows(got: &Matrix, want: &Matrix, nodes: &[usize], what: &str) {
    assert_eq!(got.rows(), nodes.len(), "{what}: row count");
    assert_eq!(got.cols(), want.cols(), "{what}: col count");
    for (i, &v) in nodes.iter().enumerate() {
        for (j, (a, b)) in got.row(i).iter().zip(want.row(v)).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{what}: node {v} col {j}: {a} vs {b}"
            );
        }
    }
}

/// Offline reference: full dense dequantize + full fused spmm.
fn reference(engine: &QuantEngine, store: &EmbeddingStore, adj: &CsrMatrix) -> (Matrix, Matrix) {
    let mut pool = BufferPool::new();
    let dense = engine.dequantize_planned(store.planned()).unwrap();
    let scores = engine
        .dequantize_spmm_planned(adj, store.planned(), &mut pool)
        .unwrap();
    (dense, scores)
}

fn check_queries(
    serve: &mut ServeEngine,
    pool: &mut BufferPool,
    queries: &[Query],
    dense: &Matrix,
    scores: &Matrix,
    what: &str,
) {
    // Naive arm: each query decodes its own blocks.
    for q in queries {
        let got = serve.answer(q, pool).unwrap();
        match q {
            Query::Embed(nodes) => assert_rows(&got, dense, nodes, &format!("{what} naive embed")),
            Query::Score(nodes) => assert_rows(&got, scores, nodes, &format!("{what} naive score")),
        }
    }
    // Batched arm: one shared decode pass over the whole set.
    let batched = serve.answer_batch(queries, pool);
    assert_eq!(batched.len(), queries.len());
    for (q, got) in queries.iter().zip(batched) {
        let got = got.unwrap();
        match q {
            Query::Embed(nodes) => assert_rows(&got, dense, nodes, &format!("{what} batch embed")),
            Query::Score(nodes) => assert_rows(&got, scores, nodes, &format!("{what} batch score")),
        }
    }
}

#[test]
fn replies_bit_identical_to_full_dequantize_under_every_isa() {
    for isa in CodecIsa::available() {
        for bits in [2u32, 4] {
            let engine = QuantEngine::from_config(&ParallelismConfig::default())
                .with_codec_isa(isa)
                .unwrap();
            let (store, adj) = store_fixture(&engine, bits);
            let (dense, scores) = reference(&engine, &store, &adj);
            let mut serve = ServeEngine::new(store, engine);
            let mut pool = BufferPool::new();
            check_queries(
                &mut serve,
                &mut pool,
                &mixed_queries(),
                &dense,
                &scores,
                &format!("isa={isa:?} bits={bits}"),
            );
            // The proof: the serving pool never handed out a dense-sized
            // float buffer. Queried neighborhoods stay below QUERY_LIMIT,
            // so at least the store's last blocks are never in any arena.
            let dense_floats = N * DIM;
            let take = pool.stats().max_float_take;
            assert!(
                take < dense_floats,
                "isa={isa:?} bits={bits}: max_float_take {take} reached dense {dense_floats}"
            );
        }
    }
}

#[test]
fn batch_counters_track_shared_decode_savings() {
    let engine = QuantEngine::from_config(&ParallelismConfig::default());
    let (store, _) = store_fixture(&engine, 2);
    let group_len = ROWS_PER_BLOCK * DIM;
    let mut serve = ServeEngine::new(store, engine);
    let mut pool = BufferPool::new();

    // Four queries over the SAME two blocks: the batch decodes each
    // block once; naive accounting (requested) says four times.
    let queries: Vec<Query> = (0..4)
        .map(|i| Query::Embed(vec![i % ROWS_PER_BLOCK, ROWS_PER_BLOCK + i % ROWS_PER_BLOCK]))
        .collect();
    let results = serve.answer_batch(&queries, &mut pool);
    assert!(results.iter().all(|r| r.is_ok()));
    let stats = serve.stats();
    assert_eq!(stats.queries, 4);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.decoded_blocks, 2, "blocks 0 and 1, each decoded once");
    assert_eq!(stats.requested_blocks, 8, "4 queries x 2 blocks each");
    // The shared arena was exactly two tiles.
    assert_eq!(pool.stats().max_float_take, 2 * group_len);

    // Per-query failure isolation: a bad node id fails ITS query with a
    // named error; batchmates still succeed.
    let queries = vec![
        Query::Embed(vec![0, 1]),
        Query::Embed(vec![N]),
        Query::Score(vec![2]),
    ];
    let results = serve.answer_batch(&queries, &mut pool);
    assert!(results[0].is_ok());
    let msg = results[1].as_ref().unwrap_err().to_string();
    assert!(msg.contains("out of range"), "{msg}");
    assert!(results[2].is_ok());

    // Empty query list: empty result, no batch counted.
    let before = serve.stats().batches;
    assert!(serve.answer_batch(&[], &mut pool).is_empty());
    assert_eq!(serve.stats().batches, before);
}

#[test]
fn transcode_reaches_int2_footprint_and_stays_bit_exact() {
    let engine = QuantEngine::from_config(&ParallelismConfig::default());
    let (mut store, adj) = store_fixture(&engine, 8);
    let wide_bytes = store.packed_resident_bytes();
    let mut pool = BufferPool::new();
    store.transcode(&engine, 2, &mut pool).unwrap();
    assert_eq!(store.bits(), 2);
    // Codes shrink 4x; per-block zero/range/width metadata is constant.
    assert!(store.packed_resident_bytes() < wide_bytes / 2);
    // Acceptance floor: packed-resident < 0.35x the dense f32 footprint
    // at INT2.
    assert!(
        (store.packed_resident_bytes() as f64) < 0.35 * store.f32_bytes() as f64,
        "{} vs {}",
        store.packed_resident_bytes(),
        store.f32_bytes()
    );
    // The transcode itself never took more than one tile.
    assert_eq!(pool.stats().max_float_take, ROWS_PER_BLOCK * DIM);

    // Replies from the transcoded store still match a full dequantize
    // OF THE TRANSCODED tensor bit-for-bit.
    let (dense, scores) = reference(&engine, &store, &adj);
    let mut serve = ServeEngine::new(store, engine);
    check_queries(
        &mut serve,
        &mut pool,
        &mixed_queries(),
        &dense,
        &scores,
        "transcoded",
    );

    // Transcoding is deterministic and engine-independent: a serial
    // engine following the same build-wide-then-narrow path lands on
    // identical bytes.
    let engine2 = QuantEngine::serial();
    let (mut store2, _) = store_fixture(&engine2, 8);
    store2.transcode(&engine2, 2, &mut pool).unwrap();
    assert_eq!(store2.planned().packed, serve.store().planned().packed);
    assert_eq!(store2.planned().zeros, serve.store().planned().zeros);
    assert_eq!(store2.planned().ranges, serve.store().planned().ranges);
}

#[test]
fn batch_queue_coalesces_concurrent_clients() {
    let engine = QuantEngine::from_config(&ParallelismConfig::default());
    let (store, adj) = store_fixture(&engine, 2);
    let (dense, scores) = reference(&engine, &store, &adj);
    let cfg = ServeConfig {
        batch_window_us: 300,
        max_batch: 16,
        ..ServeConfig::default()
    };
    let queue =
        BatchQueue::spawn(ServeEngine::new(store, engine), BufferPool::new(), &cfg).unwrap();

    std::thread::scope(|scope| {
        for t in 0..8usize {
            let client = queue.client();
            let (dense, scores) = (&dense, &scores);
            scope.spawn(move || {
                for round in 0..5usize {
                    let nodes: Vec<usize> = (0..4)
                        .map(|i| (t * 19 + round * 7 + i) % QUERY_LIMIT)
                        .collect();
                    let got = client.query(Query::Embed(nodes.clone())).unwrap();
                    assert_rows(&got, dense, &nodes, "queued embed");
                    let got = client.query(Query::Score(nodes.clone())).unwrap();
                    assert_rows(&got, scores, &nodes, "queued score");
                }
            });
        }
    });

    let (engine, pool) = queue.shutdown().unwrap();
    let stats = engine.stats();
    assert_eq!(stats.queries, 80, "8 clients x 5 rounds x 2 queries");
    assert!(
        stats.batches <= stats.queries,
        "{} batches for {} queries",
        stats.batches,
        stats.queries
    );
    assert!(stats.decoded_blocks <= stats.requested_blocks);
    assert!(pool.stats().max_float_take < N * DIM);
}

#[test]
fn tcp_round_trip_matches_offline_reference() {
    let engine = QuantEngine::from_config(&ParallelismConfig::default());
    let (store, adj) = store_fixture(&engine, 2);
    let (dense, scores) = reference(&engine, &store, &adj);
    let packed = store.packed_resident_bytes();
    let cfg = ServeConfig::default(); // port 0 = ephemeral
    let handle = ServerHandle::start(ServeEngine::new(store, engine), &cfg).unwrap();
    let addr = handle.addr();

    std::thread::scope(|scope| {
        for t in 0..4usize {
            let (dense, scores) = (&dense, &scores);
            scope.spawn(move || {
                let mut client = ServeClient::connect(&addr).unwrap();
                for round in 0..3usize {
                    let nodes: Vec<usize> = (0..5)
                        .map(|i| (t * 23 + round * 11 + i * 3) % QUERY_LIMIT)
                        .collect();
                    assert_rows(&client.embed(&nodes).unwrap(), dense, &nodes, "tcp embed");
                    assert_rows(&client.score(&nodes).unwrap(), scores, &nodes, "tcp score");
                }
            });
        }
    });

    let mut client = ServeClient::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.queries, 24, "4 clients x 3 rounds x 2 queries");
    assert_eq!(stats.packed_resident_bytes, packed);
    assert_eq!(stats.f32_bytes, N * DIM * 4);
    assert!(
        stats.packed_resident_bytes * 2 < stats.f32_bytes,
        "INT2 must be < 0.5x f32"
    );
    // Remote errors are named and leave the connection usable. (This
    // rejected query still increments the engine's `queries` counter.)
    let msg = client.embed(&[N]).unwrap_err().to_string();
    assert!(msg.contains("serve remote error"), "{msg}");
    assert!(msg.contains("out of range"), "{msg}");
    client.shutdown().unwrap();
    drop(client);

    let (stats, pool) = handle.join().unwrap();
    assert_eq!(stats.queries, 25, "24 good queries + 1 rejected");
    assert_eq!(stats.dropped_connections, 0);
    assert_eq!(stats.shed_connections, 0);
    assert_eq!(stats.timed_out_connections, 0);
    assert!(pool.stats().max_float_take < N * DIM);
}
