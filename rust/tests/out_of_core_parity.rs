//! Out-of-core parity suite (ISSUE 6).
//!
//! Disk-backed `train_partitioned` (`[out_of_core] spill_dir`) must be
//! **bit-identical** to the in-RAM path: same loss curves, same final
//! weights, same checkpoint bytes — across partition counts, halo
//! depths, fixed and heterogeneous BitPlans, and engine thread counts.
//! Streaming is a residency knob, never a numerics knob.

use iexact::config::{
    AllocStrategy, AllocationConfig, DatasetSpec, OutOfCoreConfig, ParallelismConfig,
    PartitionConfig, QuantConfig, TrainConfig,
};
use iexact::graph::Dataset;
use iexact::pipeline::{train_partitioned, PartitionTrainResult};
use std::path::PathBuf;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn tiny_ds() -> Dataset {
    DatasetSpec::tiny().generate(1)
}

/// The runtime_parity harness config, plus partitioning.
fn base_cfg(threads: usize, k: usize, halo: usize, adaptive: bool) -> TrainConfig {
    let mut cfg = TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 6,
        lr: 0.02,
        eval_every: 2,
        seeds: vec![0],
        parallelism: ParallelismConfig {
            threads,
            min_blocks_per_shard: 1,
            ..ParallelismConfig::default()
        },
        partition: PartitionConfig {
            num_partitions: k,
            halo_hops: halo,
            ..PartitionConfig::default()
        },
        ..TrainConfig::default()
    };
    if adaptive {
        cfg.allocation = AllocationConfig {
            strategy: AllocStrategy::Greedy,
            budget_bits: 2.5,
            realloc_interval_epochs: 3,
            min_bits: 1,
            max_bits: 8,
        };
    }
    cfg
}

fn unique_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("iexact_ooc_parity_{}_{tag}", std::process::id()))
}

fn assert_identical(a: &PartitionTrainResult, b: &PartitionTrainResult, what: &str) {
    assert_eq!(
        a.result.curve.train_loss, b.result.curve.train_loss,
        "{what}: train-loss curve diverged"
    );
    assert_eq!(
        a.result.curve.val_loss, b.result.curve.val_loss,
        "{what}: val-loss curve diverged"
    );
    assert_eq!(
        a.result.final_train_loss, b.result.final_train_loss,
        "{what}: final loss diverged"
    );
    assert_eq!(
        a.result.test_accuracy, b.result.test_accuracy,
        "{what}: test accuracy diverged"
    );
    assert_eq!(a.cache_bytes, b.cache_bytes, "{what}: cache bytes diverged");
    assert_eq!(
        a.model.weights.len(),
        b.model.weights.len(),
        "{what}: layer count diverged"
    );
    for (l, (wa, wb)) in a.model.weights.iter().zip(&b.model.weights).enumerate() {
        assert_eq!(
            wa.as_slice(),
            wb.as_slice(),
            "{what}: layer {l} weights diverged"
        );
    }
}

/// Serialize both models through the checkpoint writer and compare the
/// files byte for byte.
fn assert_checkpoints_byte_equal(a: &PartitionTrainResult, b: &PartitionTrainResult, tag: &str) {
    let pa = unique_dir(&format!("{tag}_ck_a.bin"));
    let pb = unique_dir(&format!("{tag}_ck_b.bin"));
    iexact::checkpoint::save(&a.model, &pa).unwrap();
    iexact::checkpoint::save(&b.model, &pb).unwrap();
    let ba = std::fs::read(&pa).unwrap();
    let bb = std::fs::read(&pb).unwrap();
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
    assert_eq!(ba, bb, "{tag}: checkpoint bytes diverged");
}

#[test]
fn disk_backed_training_is_bit_identical_to_in_ram() {
    // The ISSUE 6 acceptance matrix: K in {2,4} x halo in {1,2} x
    // {fixed, heterogeneous} plans x threads in {1,2,4}.
    let ds = tiny_ds();
    let quant = QuantConfig::int2_blockwise(4);
    for k in [2usize, 4] {
        for halo in [1usize, 2] {
            for adaptive in [false, true] {
                let reference =
                    train_partitioned(&ds, &quant, &base_cfg(1, k, halo, adaptive), 7).unwrap();
                for threads in THREAD_COUNTS {
                    let tag = format!("k{k}_h{halo}_a{}_t{threads}", adaptive as u8);
                    let dir = unique_dir(&tag);
                    let mut cfg = base_cfg(threads, k, halo, adaptive);
                    cfg.out_of_core = OutOfCoreConfig {
                        spill_dir: Some(dir.to_string_lossy().into_owned()),
                        resident_budget_bytes: 0,
                        prefetch_depth: 2,
                    };
                    let disk = train_partitioned(&ds, &quant, &cfg, 7).unwrap();
                    // The streaming run really went through the store.
                    assert!(
                        dir.join("graph").join("manifest.bin").exists(),
                        "{tag}: no chunk store was written"
                    );
                    assert!(
                        dir.join("cache").join("slot-0.spill").exists(),
                        "{tag}: no activation slot was spilled"
                    );
                    assert_identical(&reference, &disk, &tag);
                    assert_checkpoints_byte_equal(&reference, &disk, &tag);
                    std::fs::remove_dir_all(&dir).ok();
                }
            }
        }
    }
}

#[test]
fn streaming_peak_residency_is_thread_invariant() {
    // Prefetch accounting is schedule-based (manifest bytes of queued
    // chunks), so the reported peak must not depend on worker timing.
    let ds = tiny_ds();
    let quant = QuantConfig::int2_blockwise(4);
    let mut peaks = Vec::new();
    for threads in THREAD_COUNTS {
        let dir = unique_dir(&format!("peak_t{threads}"));
        let mut cfg = base_cfg(threads, 4, 1, false);
        cfg.out_of_core = OutOfCoreConfig {
            spill_dir: Some(dir.to_string_lossy().into_owned()),
            resident_budget_bytes: 0,
            prefetch_depth: 2,
        };
        let out = train_partitioned(&ds, &quant, &cfg, 3).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        peaks.push(out.peak_resident_bytes);
    }
    assert!(
        peaks.windows(2).all(|w| w[0] == w[1]),
        "peak residency varied with thread count: {peaks:?}"
    );
}

#[test]
fn prefetch_depths_do_not_change_numbers() {
    // Depth changes how far ahead chunks decode, never what trains.
    let ds = tiny_ds();
    let quant = QuantConfig::int2_blockwise(4);
    let mut runs = Vec::new();
    for depth in [0usize, 1, 4] {
        let dir = unique_dir(&format!("depth{depth}"));
        let mut cfg = base_cfg(2, 4, 1, false);
        cfg.out_of_core = OutOfCoreConfig {
            spill_dir: Some(dir.to_string_lossy().into_owned()),
            resident_budget_bytes: 0,
            prefetch_depth: depth,
        };
        let out = train_partitioned(&ds, &quant, &cfg, 11).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        runs.push(out);
    }
    for pair in runs.windows(2) {
        assert_identical(&pair[0], &pair[1], "prefetch depth sweep");
    }
}
