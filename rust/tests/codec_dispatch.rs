//! Forced-dispatch differential suite for the runtime-dispatched codec
//! (ISSUE 7).
//!
//! Every codec hot loop — `pack_codes_slice`, `unpack_range` and the
//! fused LUT dequantize — exists in up to four ISA tiers (scalar, SWAR,
//! AVX2, NEON) behind one runtime dispatch point. This suite iterates
//! every tier *available on the current host* (`CodecIsa::available()`
//! always contains `scalar` and `swar`, so the cross-checks run
//! everywhere, and the vector tiers join automatically on matching
//! hardware) and proves each one byte-identical on the packed layout
//! and bit-identical through unpack→dequantize against the retained
//! `iexact::quant::reference` oracle — across widths 1/2/4/8, ragged
//! tails, misaligned `unpack_range` starts straddling SIMD lane
//! boundaries, constant blocks and heterogeneous `BitPlan`s. Failure
//! messages carry the ISA, width, geometry and RNG seed so any
//! counterexample reproduces from the log line alone.
//!
//! The forcing knob itself is under test too: `IEXACT_CODEC_ISA` (the
//! CI dispatch matrix pins it) must be honored by `CodecIsa::active()`
//! and therefore by every default-constructed engine, and
//! `QuantEngine::with_codec_isa` must reject tiers the host cannot run.

use iexact::alloc::BitPlan;
use iexact::engine::QuantEngine;
use iexact::quant::isa::{pack_codes_slice_forced, unpack_dequantize_forced, unpack_range_forced};
use iexact::quant::{reference, BinSpec, CodecIsa, CompressedTensor};
use iexact::rngs::Pcg64;
use iexact::tensor::Matrix;

/// Miri runs the same assertions on shrunk geometry: the point there is
/// the borrow/bounds reasoning of the `unsafe` kernels, not coverage.
fn code_lengths() -> &'static [usize] {
    if cfg!(miri) {
        &[0, 1, 7, 8, 17, 65]
    } else {
        &[0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 129, 333, 1024, 1031]
    }
}

fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.next_f32() * 4.0 - 2.0)
}

fn random_codes(n: usize, bits: u32, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed);
    let max = (1u32 << bits) as u64;
    (0..n).map(|_| rng.next_bounded(max) as u8).collect()
}

#[test]
fn forced_dispatch_override_is_honored() {
    // The active path must be exactly what the env knob (or detection,
    // when unset) says — the property the whole CI matrix rests on.
    match std::env::var("IEXACT_CODEC_ISA") {
        Ok(v) => {
            let pinned = CodecIsa::parse(v.trim()).expect("CI pins only valid spellings");
            assert_eq!(CodecIsa::active(), pinned, "IEXACT_CODEC_ISA={v} not honored");
            assert_eq!(
                QuantEngine::serial().codec_isa(),
                pinned,
                "default-constructed engine ignored IEXACT_CODEC_ISA={v}"
            );
        }
        Err(_) => {
            assert_eq!(CodecIsa::active(), CodecIsa::detect());
        }
    }
    // Explicit forcing beats everything and round-trips the getter...
    for isa in CodecIsa::available() {
        let engine = QuantEngine::serial().with_codec_isa(isa).unwrap();
        assert_eq!(engine.codec_isa(), isa);
    }
    // ...and forcing an unavailable tier fails loud, never falls back.
    for isa in CodecIsa::ALL {
        if !isa.is_available() {
            let err = QuantEngine::serial().with_codec_isa(isa).unwrap_err();
            assert!(
                err.to_string().contains(isa.name()),
                "error should name the rejected tier: {err}"
            );
        }
    }
}

#[test]
fn pack_matches_reference_on_every_available_isa() {
    for bits in [1u32, 2, 4, 8] {
        for &n in code_lengths() {
            let seed = 0xD15_0000 ^ ((bits as u64) << 32) ^ n as u64;
            let codes = random_codes(n, bits, seed);
            let golden = reference::pack_codes(&codes, bits).unwrap();
            for isa in CodecIsa::available() {
                // Poisoned output buffer: a kernel that skips a byte
                // (instead of zero-padding it) fails loudly.
                let mut packed = vec![0xa5u8; golden.len()];
                pack_codes_slice_forced(isa, &codes, bits, &mut packed);
                assert_eq!(packed, golden, "isa={isa} bits={bits} n={n} seed={seed:#x}");
            }
        }
    }
}

#[test]
fn unpack_range_matches_reference_at_misaligned_starts() {
    // Starts chosen to straddle every boundary the kernels care about:
    // mid-byte (scalar head), byte (SWAR word), and the 16/32/64-code
    // SIMD group sizes of the AVX2/NEON unpack trees; lengths leave
    // ragged tails on both sides.
    let starts: &[usize] = if cfg!(miri) {
        &[0, 1, 7, 15, 16, 63, 64, 65]
    } else {
        &[0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 47, 63, 64, 65, 127, 128, 129, 255]
    };
    let lens: &[usize] = if cfg!(miri) {
        &[0, 1, 9, 33]
    } else {
        &[0, 1, 3, 7, 8, 9, 16, 31, 33, 64, 65, 100, 257]
    };
    for bits in [1u32, 2, 4, 8] {
        let n = 600;
        let seed = 0x0A11_0000 ^ bits as u64;
        let codes = random_codes(n, bits, seed);
        let packed = reference::pack_codes(&codes, bits).unwrap();
        for &start in starts {
            for &len in lens {
                if start + len > n {
                    continue;
                }
                for isa in CodecIsa::available() {
                    let mut out = vec![0xa5u8; len];
                    unpack_range_forced(isa, &packed, bits, start, &mut out);
                    assert_eq!(
                        out,
                        &codes[start..start + len],
                        "isa={isa} bits={bits} start={start} len={len} seed={seed:#x}"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_dequantize_matches_reference_bit_for_bit() {
    // The fused unpack→LUT path must reproduce the scalar two-pass
    // reconstruction exactly (compared on raw f32 bits, not with a
    // tolerance) under uniform and variance-minimized bins alike.
    let bin_specs = [
        (1u32, BinSpec::Uniform),
        (2, BinSpec::Uniform),
        (2, BinSpec::int2_vm(1.2, 1.8).unwrap()),
        (4, BinSpec::Uniform),
        (8, BinSpec::Uniform),
    ];
    for (bits, bins) in bin_specs {
        for &n in code_lengths() {
            if n == 0 {
                continue;
            }
            let seed = 0xDE0_0000 ^ ((bits as u64) << 32) ^ n as u64;
            let codes = random_codes(n, bits, seed);
            let packed = reference::pack_codes(&codes, bits).unwrap();
            let (z, r) = (-0.6875f32, 2.25f32);
            // Golden: the two-pass reference decoder over one group
            // spanning the whole stream.
            let golden_ct = CompressedTensor {
                packed: packed.clone(),
                zeros: vec![z],
                ranges: vec![r],
                shape: (1, n),
                group_len: n,
                bits,
                bins: bins.clone(),
            };
            let golden = reference::dequantize(&golden_ct).unwrap();
            let golden = golden.as_slice();
            for isa in CodecIsa::available() {
                for start in [0usize, 3, 17] {
                    if start > n {
                        continue;
                    }
                    let mut out = vec![f32::NAN; n - start];
                    unpack_dequantize_forced(isa, bits, &bins, z, r, &packed, start, &mut out);
                    let want: Vec<u32> = golden[start..].iter().map(|v| v.to_bits()).collect();
                    let got: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        got, want,
                        "isa={isa} bits={bits} n={n} start={start} seed={seed:#x}"
                    );
                }
            }
        }
    }
}

#[test]
fn constant_blocks_decode_exactly_on_every_isa() {
    // Constant input ⇒ all-zero codes and range 0: every ISA must decode
    // the block back to the constant exactly, including the all-zeros
    // packed stream the vector LUT paths see as one splatted lane.
    for bits in [1u32, 2, 4, 8] {
        let n = 200;
        let codes = vec![0u8; n];
        let packed = reference::pack_codes(&codes, bits).unwrap();
        for isa in CodecIsa::available() {
            let mut out = vec![f32::NAN; n];
            unpack_dequantize_forced(
                isa,
                bits,
                &BinSpec::Uniform,
                -1.25,
                0.0,
                &packed,
                0,
                &mut out,
            );
            assert!(
                out.iter().all(|&v| v == -1.25),
                "isa={isa} bits={bits}: constant block not exact"
            );
        }
    }
}

#[test]
fn forced_engines_agree_with_reference_end_to_end() {
    // Quantize→pack and unpack→dequantize through `QuantEngine`, pinned
    // to each available tier: packed bytes, (Z, r) metadata and the f32
    // reconstruction must all equal the serial reference oracle.
    let h = sample_matrix(17, 31, 0x15A_BEE);
    for bits in [1u32, 2, 4, 8] {
        for group_len in [8usize, 20, 7, 64] {
            let seed = 0x5EED ^ ((bits as u64) << 8) ^ (group_len as u64);
            let want =
                reference::quantize_grouped_seeded(&h, group_len, bits, &BinSpec::Uniform, seed)
                    .unwrap();
            let want_deq = reference::dequantize(&want).unwrap();
            for isa in CodecIsa::available() {
                for threads in [1usize, 4] {
                    let engine = QuantEngine::with_threads(threads).with_codec_isa(isa).unwrap();
                    let got = engine
                        .quantize_seeded(&h, group_len, bits, &BinSpec::Uniform, seed)
                        .unwrap();
                    let ctx = format!(
                        "isa={isa} bits={bits} G={group_len} t={threads} seed={seed:#x}"
                    );
                    assert_eq!(got.packed, want.packed, "packed {ctx}");
                    assert_eq!(got.zeros, want.zeros, "zeros {ctx}");
                    assert_eq!(got.ranges, want.ranges, "ranges {ctx}");
                    let deq = engine.dequantize(&got).unwrap();
                    assert_eq!(deq.as_slice(), want_deq.as_slice(), "dequant {ctx}");
                }
            }
        }
    }
}

#[test]
fn forced_engines_agree_on_heterogeneous_bitplans() {
    // 1221 scalars at G=100 → 13 blocks mixing all four widths with a
    // ragged final block (21 scalars) — the planned path every tier
    // shares through the byte-aligned per-block layout.
    let h = sample_matrix(33, 37, 0x15A_DEC);
    let plan_seed = 7u64;
    let mut rng = Pcg64::new(plan_seed);
    let widths: Vec<u8> = (0..13).map(|_| [1u8, 2, 4, 8][rng.next_bounded(4) as usize]).collect();
    let plan = BitPlan::new(widths, 100).unwrap();
    let seed = 0xFEED_u64;
    let want = reference::quantize_planned_seeded(&h, &plan, seed).unwrap();
    let want_deq = reference::dequantize_planned(&want).unwrap();
    for isa in CodecIsa::available() {
        let engine = QuantEngine::with_threads(4).with_codec_isa(isa).unwrap();
        let got = engine.quantize_planned_seeded(&h, &plan, seed).unwrap();
        let ctx = format!("isa={isa} plan_seed={plan_seed} seed={seed:#x}");
        assert_eq!(got.packed, want.packed, "packed {ctx}");
        assert_eq!(got.zeros, want.zeros, "zeros {ctx}");
        assert_eq!(got.ranges, want.ranges, "ranges {ctx}");
        let deq = engine.dequantize_planned(&got).unwrap();
        assert_eq!(deq.as_slice(), want_deq.as_slice(), "dequant {ctx}");
    }
}

#[test]
fn cross_isa_outputs_are_interchangeable() {
    // Bytes packed by one tier must unpack/decode identically through
    // every other tier — the property that makes the packed stream a
    // portable wire/checkpoint format across heterogeneous hosts.
    let bits = 2u32;
    let n = if cfg!(miri) { 96 } else { 1021 };
    let seed = 0x1177_u64;
    let codes = random_codes(n, bits, seed);
    let avail = CodecIsa::available();
    for &packer in &avail {
        let mut packed = vec![0u8; (n * bits as usize).div_ceil(8)];
        pack_codes_slice_forced(packer, &codes, bits, &mut packed);
        for &unpacker in &avail {
            let mut out = vec![0u8; n];
            unpack_range_forced(unpacker, &packed, bits, 0, &mut out);
            assert_eq!(out, codes, "pack={packer} unpack={unpacker} seed={seed:#x}");
        }
    }
}
