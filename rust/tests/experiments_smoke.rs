//! Smoke tests over the experiment harness: every paper artifact's
//! generator runs end to end at reduced scale and produces output with
//! the paper's qualitative shape.

use iexact::experiments::{fig1, fig2, fig3, fig5, table2, Effort};
use iexact::rngs::Pcg64;
use iexact::stats::ClippedNormal;

#[test]
fn fig1_panels_cover_all_bins() {
    let f = fig1::run(256, 16, 3).unwrap();
    // Uniform panel: 3 bins all populated with 256 uniform points.
    let bins: std::collections::HashSet<String> = f
        .uniform
        .iter()
        .map(|p| format!("{:.2}", p.lo))
        .collect();
    assert_eq!(bins.len(), 3);
    // Optimized boundaries are the Fig 1-B non-uniform layout.
    assert!(f.alpha < 1.0 && f.beta > 2.0 || f.alpha > 1.0 && f.beta < 2.0);
}

#[test]
fn fig2_from_synthetic_activations_prefers_cn() {
    let mut rng = Pcg64::new(1);
    let cn = ClippedNormal::new(2, 24).unwrap();
    let act =
        iexact::tensor::Matrix::from_fn(400, 24, |_, _| cn.sample(&mut rng) as f32);
    let f = fig2::from_activations(&act).unwrap();
    let (js_u, js_cn) = f.divergences().unwrap();
    assert!(js_cn < js_u);
    // CSV parses back into the right column count.
    for line in f.to_csv().lines().skip(1) {
        assert_eq!(line.split(',').count(), 4);
    }
}

#[test]
fn fig3_minimum_interior() {
    let f = fig3::run(32, 25).unwrap();
    let (a, b, v) = f.optimum;
    assert!(a > 0.0 && b < 3.0 && a < b);
    assert!(v < f.uniform);
    // Surface is symmetric-ish: Var(a, b) ≈ Var(3-b, 3-a) by μ = 1.5.
    let cn = ClippedNormal::new(2, 32).unwrap();
    let v1 = iexact::varmin::expected_sr_variance(&cn, 0.9, 1.7).unwrap();
    let v2 = iexact::varmin::expected_sr_variance(&cn, 3.0 - 1.7, 3.0 - 0.9).unwrap();
    assert!((v1 - v2).abs() < 1e-9);
}

#[test]
fn fig5_quick_effort_runs() {
    let f = fig5::run(2, 3_000, 9, |_| {}).unwrap();
    assert_eq!(f.series.len(), fig5::TRUE_DS.len());
    assert!(f.to_csv().lines().count() > 10);
}

#[test]
fn table2_on_tiny_capture() {
    // Full table2 at Quick effort exercises the capture + fit pipeline.
    let t = table2::run(Effort::Quick, |_| {}).unwrap();
    assert!(!t.rows.is_empty());
    for row in &t.rows {
        assert!(row.js_uniform.is_finite() && row.js_clipped_normal.is_finite());
        // The paper's claim: clipped normal fits better on every layer.
        assert!(
            row.js_clipped_normal < row.js_uniform,
            "{} layer {}: JS(CN)={} !< JS(U)={}",
            row.dataset,
            row.layer,
            row.js_clipped_normal,
            row.js_uniform
        );
    }
}
