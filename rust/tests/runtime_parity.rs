//! Cross-layer parity suite for the shared compute runtime (ISSUE 4).
//!
//! Every kernel that runs on the persistent
//! [`WorkerPool`](iexact::runtime::pool::WorkerPool) — the tiled dense
//! matmuls, the row-sharded spmm, and the fused dequantize→aggregate
//! kernels — must produce **bit-identical** output to its serial form at
//! any thread count, and whole training runs must be thread-count
//! invariant under the fused unstash path (fixed-width *and*
//! heterogeneous `BitPlan`s). The fused kernels must also prove, via
//! `BufferPool` stats, that they never materialize the full dense
//! dequantized matrix.

use iexact::alloc::BitPlan;
use iexact::config::{
    AllocStrategy, AllocationConfig, Arch, DatasetSpec, ParallelismConfig, QuantConfig,
    TrainConfig,
};
use iexact::engine::QuantEngine;
use iexact::graph::Dataset;
use iexact::memory::BufferPool;
use iexact::pipeline::{train, GcnModel};
use iexact::rngs::Pcg64;
use iexact::runtime::pool::WorkerPool;
use iexact::tensor::Matrix;

/// The thread counts the ISSUE 4 acceptance criteria name.
const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn random_matrix(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
    Matrix::from_fn(r, c, |_, _| rng.next_f32() * 2.0 - 1.0)
}

fn tiny_ds() -> Dataset {
    DatasetSpec::tiny().generate(1)
}

#[test]
fn matmul_family_is_bit_identical_across_thread_counts() {
    let mut rng = Pcg64::new(1);
    // Ragged shapes so tile boundaries don't align with shard counts.
    let a = random_matrix(&mut rng, 201, 67);
    let b = random_matrix(&mut rng, 67, 45);
    let c = random_matrix(&mut rng, 201, 67);
    let mm = a.matmul(&b).unwrap();
    let mt = a.matmul_transpose(&c).unwrap();
    let tm = a.transpose_matmul(&c).unwrap();
    for threads in THREAD_COUNTS {
        let pool = WorkerPool::new(threads);
        assert_eq!(
            mm.as_slice(),
            a.matmul_with(&b, &pool).unwrap().as_slice(),
            "matmul t={threads}"
        );
        assert_eq!(
            mt.as_slice(),
            a.matmul_transpose_with(&c, &pool).unwrap().as_slice(),
            "matmul_transpose t={threads}"
        );
        assert_eq!(
            tm.as_slice(),
            a.transpose_matmul_with(&c, &pool).unwrap().as_slice(),
            "transpose_matmul t={threads}"
        );
    }
}

#[test]
fn spmm_is_bit_identical_across_thread_counts() {
    let ds = tiny_ds();
    let mut rng = Pcg64::new(2);
    let h = random_matrix(&mut rng, ds.num_nodes(), 33);
    let serial = ds.adj.spmm(&h).unwrap();
    for threads in THREAD_COUNTS {
        let pool = WorkerPool::new(threads);
        assert_eq!(
            serial.as_slice(),
            ds.adj.spmm_with(&h, &pool).unwrap().as_slice(),
            "spmm t={threads}"
        );
    }
}

#[test]
fn fused_dequant_spmm_is_bit_identical_and_tile_bounded() {
    // The ISSUE 4 acceptance criterion: the fused kernel equals
    // materialize-then-aggregate bit-for-bit at every thread count, and
    // its scratch stays at one tile (block) per worker — proven by the
    // pool's largest float draw.
    let ds = tiny_ds();
    let n = ds.num_nodes();
    let r_dim = 16;
    let mut rng = Pcg64::new(3);
    let h = random_matrix(&mut rng, n, r_dim);
    let glen = 4 * r_dim; // 4 rows per block
    let num_blocks = (n * r_dim).div_ceil(glen);
    // Heterogeneous plan: every width in play.
    let bits: Vec<u8> = (0..num_blocks)
        .map(|g| [1u8, 2, 4, 8][g % 4])
        .collect();
    let plan = BitPlan::new(bits, glen).unwrap();
    let pt = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, 0xc0de)
        .unwrap();

    // Materialize-then-aggregate reference (and its full-dense draw).
    let mut mat_pool = BufferPool::new();
    let engine = QuantEngine::serial();
    let deq = engine
        .dequantize_planned_pooled(&pt, &mut mat_pool)
        .unwrap();
    let reference = ds.adj.spmm(&deq).unwrap();
    assert_eq!(
        mat_pool.stats().max_float_take,
        n * r_dim,
        "materialize path draws the full dense matrix"
    );

    for threads in THREAD_COUNTS {
        let engine = QuantEngine::with_threads(threads);
        let mut pool = BufferPool::new();
        let fused = engine
            .dequantize_spmm_planned(&ds.adj, &pt, &mut pool)
            .unwrap();
        assert_eq!(fused.as_slice(), reference.as_slice(), "t={threads}");
        assert!(
            pool.stats().max_float_take <= glen,
            "t={threads}: fused kernel drew {} floats (> one {glen}-scalar tile)",
            pool.stats().max_float_take
        );
    }
}

#[test]
fn fused_dequant_matmul_is_bit_identical_fixed_and_planned() {
    use iexact::quant::BinSpec;
    let mut rng = Pcg64::new(4);
    let h = random_matrix(&mut rng, 96, 24);
    let operand = random_matrix(&mut rng, 24, 40);

    // Fixed-width stash (the backward's CompressedTensor path).
    let ct = QuantEngine::serial()
        .quantize_seeded(&h, 48, 2, &BinSpec::Uniform, 11)
        .unwrap();
    let ref_fixed = QuantEngine::serial()
        .dequantize(&ct)
        .unwrap()
        .matmul(&operand)
        .unwrap();
    // Heterogeneous plan (the adaptive-allocation path).
    let plan = BitPlan::new(
        (0..48).map(|g| [1u8, 2, 4, 8][g % 4]).collect(),
        48,
    )
    .unwrap();
    let pt = QuantEngine::serial()
        .quantize_planned_seeded(&h, &plan, 12)
        .unwrap();
    let ref_planned = QuantEngine::serial()
        .dequantize_planned(&pt)
        .unwrap()
        .matmul(&operand)
        .unwrap();

    for threads in THREAD_COUNTS {
        let engine = QuantEngine::with_threads(threads);
        let mut pool = BufferPool::new();
        let fused = engine.dequantize_matmul(&ct, &operand, &mut pool).unwrap();
        assert_eq!(fused.as_slice(), ref_fixed.as_slice(), "fixed t={threads}");
        let fused = engine
            .dequantize_matmul_planned(&pt, &operand, &mut pool)
            .unwrap();
        assert_eq!(
            fused.as_slice(),
            ref_planned.as_slice(),
            "planned t={threads}"
        );
        assert!(
            pool.stats().max_float_take <= 48,
            "t={threads}: {} floats drawn",
            pool.stats().max_float_take
        );
    }
}

#[test]
fn pooled_forward_matches_serial_forward() {
    let ds = tiny_ds();
    let mut rng = Pcg64::new(5);
    for arch in [Arch::Gcn, Arch::GraphSage] {
        let model =
            GcnModel::init_arch(arch, ds.num_features(), 32, ds.num_classes, 3, &mut rng)
                .unwrap();
        let serial = model.forward(&ds).unwrap();
        for threads in THREAD_COUNTS {
            let pool = WorkerPool::new(threads);
            let par = model.forward_with(&ds, &pool).unwrap();
            assert_eq!(serial.as_slice(), par.as_slice(), "{arch:?} t={threads}");
        }
    }
}

fn thread_cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        hidden_dim: 32,
        num_layers: 3,
        epochs: 8,
        lr: 0.02,
        eval_every: 2,
        seeds: vec![0],
        parallelism: ParallelismConfig {
            threads,
            min_blocks_per_shard: 1,
            ..ParallelismConfig::default()
        },
        ..TrainConfig::default()
    }
}

#[test]
fn training_curves_are_thread_invariant_under_fused_path() {
    // Whole-run invariance: the fused unstash + tiled kernels must keep
    // the loss trajectory bit-identical at every thread count, for both
    // architectures at fixed width.
    let ds = tiny_ds();
    for (arch, quant) in [
        (Arch::Gcn, QuantConfig::int2_blockwise(4)),
        (Arch::Gcn, QuantConfig::int2_vm()),
        (Arch::GraphSage, QuantConfig::int2_blockwise(4)),
    ] {
        let mut serial_cfg = thread_cfg(1);
        serial_cfg.arch = arch;
        let reference = train(&ds, &quant, &serial_cfg, 5).unwrap();
        for threads in [2usize, 4, 7] {
            let mut cfg = thread_cfg(threads);
            cfg.arch = arch;
            let run = train(&ds, &quant, &cfg, 5).unwrap();
            assert_eq!(
                reference.curve.train_loss, run.curve.train_loss,
                "{arch:?} {} t={threads}: loss curve diverged",
                quant.label()
            );
            assert_eq!(reference.curve.val_loss, run.curve.val_loss);
            assert_eq!(reference.test_accuracy, run.test_accuracy);
            assert_eq!(reference.final_train_loss, run.final_train_loss);
        }
    }
}

#[test]
fn adaptive_training_is_thread_invariant_under_fused_path() {
    // Same invariance under heterogeneous BitPlans: the adaptive
    // allocator re-plans mid-run and the fused planned unstash must stay
    // bit-identical serial vs parallel.
    let ds = tiny_ds();
    let quant = QuantConfig::int2_blockwise(4);
    let allocation = AllocationConfig {
        strategy: AllocStrategy::Greedy,
        budget_bits: 2.5,
        realloc_interval_epochs: 3,
        min_bits: 1,
        max_bits: 8,
    };
    let mut serial_cfg = thread_cfg(1);
    serial_cfg.allocation = allocation.clone();
    let reference = train(&ds, &quant, &serial_cfg, 9).unwrap();
    for threads in [2usize, 4, 7] {
        let mut cfg = thread_cfg(threads);
        cfg.allocation = allocation.clone();
        let run = train(&ds, &quant, &cfg, 9).unwrap();
        assert_eq!(
            reference.curve.train_loss, run.curve.train_loss,
            "adaptive t={threads}: loss curve diverged"
        );
        assert_eq!(reference.final_train_loss, run.final_train_loss);
        assert_eq!(reference.test_accuracy, run.test_accuracy);
    }
}
