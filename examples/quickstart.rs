//! Quickstart: generate a small synthetic graph, train a GCN with the
//! paper's INT2 block-wise activation compression, and compare against
//! the FP32 baseline — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use iexact::prelude::*;
use iexact::config::TrainConfig;

fn main() -> iexact::Result<()> {
    // 1. A small synthetic dataset (256 nodes, 4 classes).
    let dataset = DatasetSpec::tiny().generate(42);
    println!(
        "dataset: {} nodes, {} edges, {} features, {} classes",
        dataset.num_nodes(),
        dataset.num_edges(),
        dataset.num_features(),
        dataset.num_classes
    );

    let cfg = TrainConfig {
        hidden_dim: 64,
        num_layers: 3,
        epochs: 40,
        eval_every: 5,
        ..TrainConfig::default()
    };

    // 2. FP32 baseline.
    let fp32 = iexact::pipeline::train(&dataset, &QuantConfig::fp32(), &cfg, 0)?;

    // 3. Extreme compression: INT2, random projection D/R=8, block-wise
    //    quantization with G/R = 64 (the paper's headline config).
    let quant = QuantConfig::int2_blockwise(64);
    let compressed = iexact::pipeline::train(&dataset, &quant, &cfg, 0)?;

    // 4. Compare accuracy and activation memory.
    let mem = MemoryModel::new(
        dataset.num_nodes(),
        dataset.num_features(),
        cfg.hidden_dim,
        cfg.num_layers,
    );
    println!("\n{:<22} {:>10} {:>14}", "config", "test acc", "activation KB");
    println!("{}", "-".repeat(48));
    println!(
        "{:<22} {:>10.4} {:>14.1}",
        "FP32 baseline",
        fp32.test_accuracy,
        mem.breakdown(&QuantConfig::fp32())?.total as f64 / 1024.0
    );
    println!(
        "{:<22} {:>10.4} {:>14.1}",
        quant.label(),
        compressed.test_accuracy,
        mem.breakdown(&quant)?.total as f64 / 1024.0
    );
    println!(
        "\nmeasured stash bytes: fp32 = {} KB, compressed = {} KB ({}x smaller)",
        fp32.stash_bytes / 1024,
        compressed.stash_bytes / 1024,
        fp32.stash_bytes / compressed.stash_bytes.max(1)
    );
    println!(
        "accuracy delta: {:+.4} (the paper's finding: ~no change)",
        compressed.test_accuracy - fp32.test_accuracy
    );
    Ok(())
}
