//! Variance-minimization analysis driver: regenerates Table 2 and
//! Figures 3, 4 and 5 (the Appendix B/C validation suite).
//!
//! Run: `cargo run --release --example varmin_analysis [-- --effort paper]`

use iexact::experiments::{fig3, fig4, fig5, table2, Effort};

fn main() -> iexact::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let effort = args
        .iter()
        .position(|a| a == "--effort")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| Effort::parse(s))
        .unwrap_or(Effort::Quick);
    std::fs::create_dir_all("results").ok();

    eprintln!("== Table 2: JS divergence + variance reduction ==");
    let t2 = table2::run(effort, |l| eprintln!("{l}"))?;
    println!("{}", t2.render());
    std::fs::write("results/table2.csv", t2.to_csv())?;

    eprintln!("== Fig 3: SR variance surface ==");
    let f3 = fig3::run(16, if effort == Effort::Paper { 60 } else { 30 })?;
    println!("{}", f3.render());
    std::fs::write("results/fig3.csv", f3.to_csv())?;

    eprintln!("== Fig 4: variance reduction vs assumed D ==");
    let f4 = fig4::run(effort, |l| eprintln!("{l}"))?;
    println!("{}", f4.render());
    std::fs::write("results/fig4.csv", f4.to_csv())?;

    eprintln!("== Fig 5: CN_[1/D] reduction curves ==");
    let (trials, samples) = if effort == Effort::Paper {
        (10, 20_000)
    } else {
        (4, 6_000)
    };
    let f5 = fig5::run(trials, samples, 0, |l| eprintln!("{l}"))?;
    println!("{}", f5.render());
    std::fs::write("results/fig5.csv", f5.to_csv())?;

    eprintln!("csvs written to results/");
    Ok(())
}
