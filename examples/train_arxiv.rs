//! End-to-end driver (DESIGN.md §5 E2E): trains the 3-layer GCN on the
//! arxiv-like dataset through **both** paths —
//!
//! 1. the native Rust pipeline, and
//! 2. the full three-layer stack: JAX/Pallas-authored training step,
//!    AOT-lowered to HLO, executed from Rust via PJRT —
//!
//! for a few hundred steps, logging the loss curve. This proves all the
//! layers compose. The AOT path is exercised when `artifacts/` exists
//! (build with `make artifacts`); otherwise the example reports how to
//! enable it and still completes the native run.
//!
//! Run: `cargo run --release --example train_arxiv [-- --epochs 200]`

use iexact::config::{DatasetSpec, QuantConfig, TrainConfig};
use iexact::coordinator::AotCoordinator;
use iexact::runtime::Runtime;

fn main() -> iexact::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args
        .iter()
        .position(|a| a == "--epochs")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    // ---------- native path ----------
    let spec = DatasetSpec::arxiv_like();
    let dataset = spec.generate(42);
    println!(
        "[native] {}: {} nodes / {} edges / {} feats / {} classes",
        spec.name,
        dataset.num_nodes(),
        dataset.num_edges(),
        dataset.num_features(),
        dataset.num_classes
    );
    let cfg = TrainConfig {
        hidden_dim: 128,
        num_layers: 3,
        epochs,
        eval_every: 10,
        ..TrainConfig::default()
    };
    let quant = QuantConfig::int2_blockwise(64);
    let res = iexact::pipeline::train(&dataset, &quant, &cfg, 0)?;
    println!("[native] loss curve (epoch, train_loss, val_loss, val_acc):");
    for i in 0..res.curve.epochs.len() {
        println!(
            "[native]   {:>4}  {:.4}  {:.4}  {:.4}",
            res.curve.epochs[i],
            res.curve.train_loss[i],
            res.curve.val_loss[i],
            res.curve.val_accuracy[i]
        );
    }
    println!(
        "[native] test acc {:.4} | {:.2} epochs/s | stash {} KB",
        res.test_accuracy,
        res.epochs_per_sec,
        res.stash_bytes / 1024
    );

    // ---------- AOT path ----------
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\n[aot] artifacts/manifest.json not found — run `make artifacts` to");
        println!("[aot] build the JAX/Pallas AOT modules and re-run this example.");
        return Ok(());
    }
    let mut rt = Runtime::open(artifacts)?;
    println!("\n[aot] platform: {}", rt.platform());
    let slug = quant.slug();
    let name = format!("train_step_arxiv_{slug}");
    let entry = rt.load(&name)?.entry.clone();
    let aot_spec = DatasetSpec {
        num_nodes: entry.meta["num_nodes"].parse().unwrap(),
        num_features: entry.meta["num_features"].parse().unwrap(),
        num_classes: entry.meta["num_classes"].parse().unwrap(),
        ..DatasetSpec::arxiv_like()
    };
    let aot_ds = aot_spec.generate(42);
    println!(
        "[aot] {}: {} nodes (AOT-scale), quant {}",
        aot_spec.name,
        aot_ds.num_nodes(),
        quant.label()
    );
    let aot_epochs = epochs.min(120);
    let mut coord = AotCoordinator::new(&mut rt, "arxiv", &slug, &aot_ds, 0)?;
    let out = coord.train(&slug, &aot_ds, aot_epochs, 10)?;
    println!("[aot] loss curve (epoch, train_loss, val_loss, val_acc):");
    for i in 0..out.curve.epochs.len() {
        println!(
            "[aot]   {:>4}  {:.4}  {:.4}  {:.4}",
            out.curve.epochs[i],
            out.curve.train_loss[i],
            out.curve.val_loss[i],
            out.curve.val_accuracy[i]
        );
    }
    println!(
        "[aot] test acc {:.4} | {:.2} steps/s (JAX graph + Pallas kernel via PJRT)",
        out.test_accuracy, out.epochs_per_sec
    );
    Ok(())
}
