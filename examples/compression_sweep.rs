//! Table 1 driver: sweeps FP32 / EXACT / block-wise G/R ∈ {2..64} / VM
//! over both paper datasets and prints the paper-format table.
//!
//! Run: `cargo run --release --example compression_sweep [-- --effort paper]`

use iexact::experiments::{table1, Effort};

fn main() -> iexact::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let effort = args
        .iter()
        .position(|a| a == "--effort")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| Effort::parse(s))
        .unwrap_or(Effort::Quick);

    eprintln!("running Table 1 sweep at effort {effort:?}…");
    let t = table1::run(effort, |line| eprintln!("{line}"))?;
    println!("\n{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table1.csv", t.to_csv())?;
    eprintln!("csv written to results/table1.csv");
    Ok(())
}
