//! Fig 2 driver: captures normalized projected activations from a trained
//! GNN, renders the observed density next to the uniform and
//! clipped-normal models, and reports the JS divergences (Fig. 1/2 of the
//! paper's distribution-modelling argument).
//!
//! Run: `cargo run --release --example distribution_fit [-- --effort paper]`

use iexact::experiments::{fig1, fig2, Effort};

fn main() -> iexact::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let effort = args
        .iter()
        .position(|a| a == "--effort")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| Effort::parse(s))
        .unwrap_or(Effort::Quick);
    std::fs::create_dir_all("results").ok();

    eprintln!("== Fig 1: stochastic rounding demo ==");
    let f1 = fig1::run(128, 16, 0)?;
    println!("{}", f1.render());
    std::fs::write("results/fig1.csv", f1.to_csv())?;

    eprintln!("== Fig 2: observed vs modelled activation densities ==");
    let f2 = fig2::run(effort)?;
    println!("{}", f2.render());
    let (js_u, js_cn) = f2.divergences()?;
    println!("JS(observed, uniform)        = {js_u:.4}");
    println!("JS(observed, clipped normal) = {js_cn:.4}");
    println!(
        "clipped normal is {}x closer — the paper's Fig 2/Table 2 claim",
        (js_u / js_cn.max(1e-9)) as u32
    );
    std::fs::write("results/fig2.csv", f2.to_csv())?;
    eprintln!("csvs written to results/");
    Ok(())
}
