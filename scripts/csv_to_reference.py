#!/usr/bin/env python
"""Convert the Rust `boundaries` CSV into the golden JSON consumed by
python/tests/test_varmin.py (cross-implementation check)."""

import csv
import json
import sys


def main() -> None:
    src, dst = sys.argv[1], sys.argv[2]
    out = {}
    with open(src) as fh:
        for row in csv.DictReader(fh):
            out[int(row["D"])] = [float(row["alpha*"]), float(row["beta*"])]
    with open(dst, "w") as fh:
        json.dump(out, fh, indent=1, sort_keys=True)
    print(f"wrote {len(out)} golden boundary pairs to {dst}")


if __name__ == "__main__":
    main()
