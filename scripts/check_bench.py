#!/usr/bin/env python3
"""Sanity-parse the machine-readable bench trajectory.

``cargo bench --bench bench_pipeline`` writes ``BENCH_pipeline.json``
(per-arm epoch time, throughput, peak-resident activation bytes and
speedup vs. the arm group's serial baseline). This script validates the
schema and basic invariants so CI catches a malformed emitter before the
file is archived as the repo's perf trajectory, and prints a compact
summary table.

Usage:
    python3 scripts/check_bench.py [path/to/BENCH_pipeline.json]

Exit status is non-zero on a malformed file. Absolute timings are
machine-dependent, so the script checks structure and sanity (positive
times, consistent rates), not performance thresholds — those live in the
bench output itself (the ``threads`` group records speedup_vs_serial).
"""

import json
import sys

REQUIRED_ARM_KEYS = {
    "group": str,
    "name": str,
    "ms_per_epoch": (int, float),
    "rate_per_sec": (int, float),
    "peak_resident_bytes": int,
    "speedup_vs_serial": (int, float),
}

EXPECTED_GROUPS = {"table1", "allocation", "partition", "threads", "fused"}


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipeline.json"
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found (run `cargo bench --bench bench_pipeline` first)")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if doc.get("bench") != "pipeline":
        fail(f"unexpected bench id {doc.get('bench')!r}")
    ds = doc.get("dataset")
    if not isinstance(ds, dict) or not all(
        isinstance(ds.get(k), int) and ds[k] > 0 for k in ("nodes", "edges", "hidden")
    ):
        fail(f"malformed dataset header: {ds!r}")

    arms = doc.get("arms")
    if not isinstance(arms, list) or not arms:
        fail("no benchmark arms recorded")
    for arm in arms:
        for key, typ in REQUIRED_ARM_KEYS.items():
            if key not in arm:
                fail(f"arm {arm.get('name')!r} missing key {key!r}")
            if not isinstance(arm[key], typ):
                fail(f"arm {arm.get('name')!r}: {key} has type {type(arm[key]).__name__}")
        if arm["ms_per_epoch"] <= 0 or arm["rate_per_sec"] <= 0:
            fail(f"arm {arm['name']!r}: non-positive timing")
        if arm["peak_resident_bytes"] < 0 or arm["speedup_vs_serial"] <= 0:
            fail(f"arm {arm['name']!r}: negative memory or speedup")
        # ms/epoch and epochs/s must describe the same measurement.
        recomputed = 1000.0 / arm["ms_per_epoch"]
        if abs(recomputed - arm["rate_per_sec"]) > 0.02 * max(recomputed, 1e-9):
            fail(
                f"arm {arm['name']!r}: rate {arm['rate_per_sec']} inconsistent "
                f"with ms_per_epoch {arm['ms_per_epoch']}"
            )

    groups = {a["group"] for a in arms}
    missing = EXPECTED_GROUPS - groups
    if missing:
        fail(f"missing arm groups: {sorted(missing)}")

    print(
        f"check_bench: OK — {len(arms)} arms over {sorted(groups)} "
        f"({ds['nodes']} nodes, {ds['edges']} edges, hidden {ds['hidden']})"
    )
    print(f"{'group':<12} {'arm':<24} {'ms/epoch':>10} {'peak KB':>9} {'speedup':>8}")
    for arm in arms:
        print(
            f"{arm['group']:<12} {arm['name']:<24} {arm['ms_per_epoch']:>10.2f} "
            f"{arm['peak_resident_bytes'] // 1024:>9} {arm['speedup_vs_serial']:>7.2f}x"
        )
    threads = [a for a in arms if a["group"] == "threads"]
    best = max((a["speedup_vs_serial"] for a in threads), default=1.0)
    print(f"check_bench: best end-to-end thread speedup vs serial: {best:.2f}x")


if __name__ == "__main__":
    main()
