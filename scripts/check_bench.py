#!/usr/bin/env python3
"""Validate the machine-readable bench trajectory and gate perf regressions.

``cargo bench --bench bench_pipeline`` writes ``BENCH_pipeline.json``
(per-arm epoch time, throughput, peak-resident activation bytes and
speedup vs. the arm group's serial baseline); ``cargo bench --bench
bench_quant`` writes ``BENCH_quant.json`` (the ``codec`` group: fused
word-parallel codec vs. the two-pass reference). This script has two
modes:

**Schema mode** (default)::

    python3 scripts/check_bench.py [path/to/BENCH_*.json]

validates the file's structure and basic invariants (positive times,
consistent rates, expected arm groups per bench id) so CI catches a
malformed emitter before the file is archived, and prints a summary
table.

**Baseline mode**::

    python3 scripts/check_bench.py BENCH_pipeline.json \
        --baseline BENCH_baseline.json --tolerance 0.10

additionally compares the current run against a committed baseline and
exits non-zero when any gated arm (groups ``table1``/``fused``/
``threads``/``serve`` by default, override with ``--groups``) regressed
by more than the tolerance. Absolute wall-clock is machine-dependent, so the
comparison is **anchored**: each arm's time ratio (current/baseline) is
normalized by its group's anchor arm (``FP32``, ``threads=1``,
``materialize t=1``), which cancels the machine-speed factor; the
anchors themselves are cross-checked against the median anchor ratio.
A PR that intentionally shifts the perf profile re-blesses the baseline
by committing the CI run's ``BENCH_pipeline.json`` artifact as
``BENCH_baseline.json`` verbatim.

A baseline whose ``provenance`` field is ``"bootstrap"`` (hand-seeded,
not measured on reference hardware) is compared in **report-only** mode:
regressions are printed but do not fail the job. A measured baseline
(no ``provenance`` field — the bench emitter writes none) gates hard.
"""

import argparse
import json
import statistics
import sys

REQUIRED_ARM_KEYS = {
    "group": str,
    "name": str,
    "ms_per_epoch": (int, float),
    "rate_per_sec": (int, float),
    "peak_resident_bytes": int,
    "speedup_vs_serial": (int, float),
}

# Expected arm groups and dataset-header fields per bench id.
EXPECTED_GROUPS = {
    "pipeline": {
        "table1",
        "allocation",
        "partition",
        "threads",
        "fused",
        "ooc",
        "dist",
        "chaos",
        "serve",
    },
    "quant": {"codec"},
}

# Groups added after the committed baseline was last blessed: required
# in a current run, tolerated as absent from a baseline file until the
# baseline is re-blessed. Their regression gating is report-only by
# default regardless (they are not in DEFAULT_GATED_GROUPS).
POST_BASELINE_GROUPS = {"dist", "chaos"}

# Extra per-arm keys the serve group must carry (query latency
# percentiles; throughput rides in the standard rate_per_sec field).
SERVE_ARM_KEYS = ("p50_us", "p99_us")

# Extra per-arm keys the chaos group must carry: the fault-recovery
# tally of the run the arm timed. The clean anchor arm must record
# zero of both; the faulted arm must have seen at least one death AND
# one elastic restart, otherwise the arm silently measured a fault-free
# run and its "fault-tolerance overhead" number is fiction.
CHAOS_ARM_KEYS = ("deaths", "restarts")
DATASET_KEYS = {
    "pipeline": ("nodes", "edges", "hidden"),
    "quant": ("rows", "cols"),
}

# Group → anchor-arm name used to cancel the machine-speed factor in
# baseline mode. An arm regressed iff it got slower *relative to its
# group's anchor* (and anchors are cross-checked among themselves).
GROUP_ANCHORS = {
    "table1": "FP32",
    "threads": "threads=1",
    "fused": "materialize t=1",
    "allocation": "fixed int2",
    "partition": "K=1",
    "ooc": "in-ram K=32",
    "dist": "K=4 workers=2",
    "chaos": "clean K=4 w=2",
    "serve": "naive c=8",
}

DEFAULT_GATED_GROUPS = ["table1", "fused", "threads", "serve"]

# Arms whose *baseline* time is below this get a doubled tolerance:
# sub-millisecond kernels (the fused group) are measured over a handful
# of iterations and shared-runner scheduler noise routinely exceeds a
# 10% band at that duration. The widened band still catches the 2x-class
# regressions a codec bug would cause.
SHORT_ARM_MS = 5.0


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found (run the matching `cargo bench` first)")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


def validate(doc: dict, path: str, baseline: bool = False) -> str:
    """Schema-check one trajectory file; returns its bench id."""
    bench = doc.get("bench")
    if bench not in EXPECTED_GROUPS:
        fail(f"{path}: unexpected bench id {bench!r}")
    ds = doc.get("dataset")
    keys = DATASET_KEYS[bench]
    if not isinstance(ds, dict) or not all(
        isinstance(ds.get(k), int) and ds[k] > 0 for k in keys
    ):
        fail(f"{path}: malformed dataset header {ds!r} (needs {keys})")

    arms = doc.get("arms")
    if not isinstance(arms, list) or not arms:
        fail(f"{path}: no benchmark arms recorded")
    for arm in arms:
        for key, typ in REQUIRED_ARM_KEYS.items():
            if key not in arm:
                fail(f"{path}: arm {arm.get('name')!r} missing key {key!r}")
            if not isinstance(arm[key], typ):
                fail(
                    f"{path}: arm {arm.get('name')!r}: {key} has type "
                    f"{type(arm[key]).__name__}"
                )
        if arm["ms_per_epoch"] <= 0 or arm["rate_per_sec"] <= 0:
            fail(f"{path}: arm {arm['name']!r}: non-positive timing")
        if arm["peak_resident_bytes"] < 0 or arm["speedup_vs_serial"] <= 0:
            fail(f"{path}: arm {arm['name']!r}: negative memory or speedup")
        # ms/epoch and epochs/s must describe the same measurement.
        recomputed = 1000.0 / arm["ms_per_epoch"]
        if abs(recomputed - arm["rate_per_sec"]) > 0.02 * max(recomputed, 1e-9):
            fail(
                f"{path}: arm {arm['name']!r}: rate {arm['rate_per_sec']} "
                f"inconsistent with ms_per_epoch {arm['ms_per_epoch']}"
            )
        if arm["group"] == "serve":
            for key in SERVE_ARM_KEYS:
                if not isinstance(arm.get(key), (int, float)) or arm[key] <= 0:
                    fail(
                        f"{path}: serve arm {arm['name']!r} needs positive "
                        f"{key!r}, got {arm.get(key)!r}"
                    )
            if arm["p50_us"] > arm["p99_us"]:
                fail(
                    f"{path}: serve arm {arm['name']!r}: p50 "
                    f"{arm['p50_us']} above p99 {arm['p99_us']}"
                )
        if arm["group"] == "chaos":
            for key in CHAOS_ARM_KEYS:
                val = arm.get(key)
                if not isinstance(val, (int, float)) or val < 0:
                    fail(
                        f"{path}: chaos arm {arm['name']!r} needs non-negative "
                        f"{key!r}, got {val!r}"
                    )
            clean = arm["name"].startswith("clean")
            if clean and (arm["deaths"] != 0 or arm["restarts"] != 0):
                fail(
                    f"{path}: chaos anchor {arm['name']!r} recorded faults "
                    f"(deaths={arm['deaths']}, restarts={arm['restarts']}) — "
                    "the clean arm must be fault-free"
                )
            if not clean and (arm["deaths"] < 1 or arm["restarts"] < 1):
                fail(
                    f"{path}: chaos arm {arm['name']!r} saw no death/restart "
                    f"(deaths={arm['deaths']}, restarts={arm['restarts']}) — "
                    "it measured a fault-free run"
                )

    groups = {a["group"] for a in arms}
    missing = EXPECTED_GROUPS[bench] - groups
    if baseline:
        missing -= POST_BASELINE_GROUPS
    if missing:
        fail(f"{path}: missing arm groups: {sorted(missing)}")
    return bench


def print_summary(doc: dict, bench: str) -> None:
    arms = doc["arms"]
    ds = doc["dataset"]
    shape = ", ".join(f"{k}={ds[k]}" for k in DATASET_KEYS[bench])
    print(
        f"check_bench: OK — {len(arms)} arms over "
        f"{sorted({a['group'] for a in arms})} ({shape})"
    )
    print(f"{'group':<12} {'arm':<24} {'ms/epoch':>10} {'peak KB':>9} {'speedup':>8}")
    for arm in arms:
        print(
            f"{arm['group']:<12} {arm['name']:<24} {arm['ms_per_epoch']:>10.2f} "
            f"{arm['peak_resident_bytes'] // 1024:>9} {arm['speedup_vs_serial']:>7.2f}x"
        )
    threads = [a for a in arms if a["group"] == "threads"]
    if threads:
        best = max(a["speedup_vs_serial"] for a in threads)
        print(f"check_bench: best end-to-end thread speedup vs serial: {best:.2f}x")
    codec = [a for a in arms if a["group"] == "codec" and a["name"].startswith("fused")]
    if codec:
        best = max(a["speedup_vs_serial"] for a in codec)
        print(f"check_bench: best fused-codec speedup vs two-pass: {best:.2f}x")
    serve = [a for a in arms if a["group"] == "serve"]
    for arm in serve:
        print(
            f"check_bench: serve '{arm['name']}': p50 {arm['p50_us']:.1f} us, "
            f"p99 {arm['p99_us']:.1f} us, {arm['rate_per_sec']:.0f} q/s, "
            f"packed {arm['peak_resident_bytes']} B"
        )
    batched = [a for a in serve if a["name"].startswith("batched")]
    if batched:
        best = max(a["speedup_vs_serial"] for a in batched)
        print(f"check_bench: serve batched-over-naive throughput: {best:.2f}x")
    chaos = [a for a in arms if a["group"] == "chaos"]
    for arm in chaos:
        print(
            f"check_bench: chaos '{arm['name']}': {arm['ms_per_epoch']:.2f} "
            f"ms/epoch, deaths={arm['deaths']:.0f}, restarts={arm['restarts']:.0f}"
        )


def compare_to_baseline(cur: dict, base: dict, tolerance: float, groups: list) -> None:
    """Anchored per-arm regression gate; exits non-zero on failure."""
    bootstrap = base.get("provenance") == "bootstrap"
    cur_by_key = {(a["group"], a["name"]): a for a in cur["arms"]}
    base_gated = [a for a in base["arms"] if a["group"] in groups]
    if not base_gated:
        fail(f"baseline has no arms in gated groups {groups}")

    # Raw time ratios current/baseline per matched arm.
    ratios = {}
    for arm in base_gated:
        key = (arm["group"], arm["name"])
        if key not in cur_by_key:
            fail(f"gated baseline arm {key} missing from current run")
        ratios[key] = cur_by_key[key]["ms_per_epoch"] / arm["ms_per_epoch"]

    # Anchor ratio per group cancels the machine-speed factor.
    anchor_ratio = {}
    for group in groups:
        anchor = GROUP_ANCHORS.get(group)
        key = (group, anchor)
        if anchor is None or key not in ratios:
            fail(f"group {group!r} has no anchor arm in both runs")
        anchor_ratio[group] = ratios[key]

    # Anchors are cross-checked against the median anchor ratio over
    # EVERY anchored group present in both runs (not only the gated
    # ones) — otherwise gating a single group would normalize its
    # anchor against itself and an anchor regression could never fire.
    base_by_key = {(a["group"], a["name"]): a for a in base["arms"]}
    all_anchor_ratios = []
    for group, anchor in GROUP_ANCHORS.items():
        key = (group, anchor)
        if key in cur_by_key and key in base_by_key:
            all_anchor_ratios.append(
                cur_by_key[key]["ms_per_epoch"] / base_by_key[key]["ms_per_epoch"]
            )

    regressions = []
    print(
        f"\ncheck_bench: baseline comparison (tolerance {tolerance:.0%}, "
        f"2x band under {SHORT_ARM_MS} ms, "
        f"groups {groups}{', BOOTSTRAP baseline — report only' if bootstrap else ''})"
    )
    print(f"{'group':<12} {'arm':<24} {'vs baseline':>12} {'anchored':>10} {'gate':>8}")
    median_anchor = statistics.median(all_anchor_ratios)
    for key, ratio in ratios.items():
        group, name = key
        if name == GROUP_ANCHORS.get(group):
            # Anchors gate against the median anchor ratio so a
            # regression in an anchor itself is not invisible.
            normalized = ratio / median_anchor
        else:
            normalized = ratio / anchor_ratio[group]
        tol = tolerance * 2 if base_by_key[key]["ms_per_epoch"] < SHORT_ARM_MS else tolerance
        regressed = normalized > 1.0 + tol
        print(
            f"{group:<12} {name:<24} {ratio:>11.2f}x {normalized:>9.2f}x "
            f"{'REGRESS' if regressed else 'ok':>8}"
        )
        if regressed:
            regressions.append((key, normalized))

    # The fused dequantize-path arms, reported as throughput multipliers
    # vs. the committed baseline. Normalized by the *median* anchor
    # ratio (not the fused group's own anchor, whose decode path also
    # speeds up with the codec) so the number is machine-independent yet
    # not self-discounting.
    fused = [
        (name, median_anchor / ratio)
        for (group, name), ratio in ratios.items()
        if group == "fused" and name.startswith("fused")
    ]
    for name, gain in fused:
        print(
            f"check_bench: dequantize-path throughput on '{name}': "
            f"{gain:.2f}x vs baseline (anchored)"
        )

    if regressions:
        msg = ", ".join(f"{k} {r:.2f}x" for k, r in regressions)
        if bootstrap:
            print(
                "check_bench: NOTE: regressions vs the bootstrap baseline are "
                f"report-only until a measured baseline is blessed: {msg}"
            )
        else:
            fail(f">{tolerance:.0%} per-epoch regression in gated arms: {msg}")
    else:
        print("check_bench: no gated regression vs baseline")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="BENCH_pipeline.json")
    ap.add_argument("--baseline", help="committed baseline JSON to gate against")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.10,
        help="allowed anchored per-arm slowdown (default 0.10 = 10%%)",
    )
    ap.add_argument(
        "--groups",
        default=",".join(DEFAULT_GATED_GROUPS),
        help="comma-separated arm groups to gate (default table1,fused,threads,serve)",
    )
    args = ap.parse_args()

    doc = load(args.path)
    bench = validate(doc, args.path)
    print_summary(doc, bench)

    if args.baseline:
        if bench != "pipeline":
            fail("--baseline comparison is defined for the pipeline bench")
        base = load(args.baseline)
        if validate(base, args.baseline, baseline=True) != "pipeline":
            fail(f"{args.baseline} is not a pipeline trajectory")
        compare_to_baseline(
            doc, base, args.tolerance, [g for g in args.groups.split(",") if g]
        )


if __name__ == "__main__":
    main()
