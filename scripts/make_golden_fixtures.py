#!/usr/bin/env python3
"""Generate the golden packed-format fixtures for rust/tests/golden_pack.rs.

This is a deliberately independent, bit-exact port of the Rust crate's
PCG64 stream addressing (rust/src/rngs.rs) and the uniform-bins
stochastic-rounding kernel + LSB-first packing (rust/src/quant.rs), so
the committed fixtures cross-check the Rust implementation against a
second implementation rather than against itself.

Exactness argument: every floating-point step in the fixture pipeline is
either integer math, an exact power-of-two scale, or a single IEEE-754
float32 operation (numpy float32 ops round identically to Rust f32), so
the two implementations agree byte-for-byte. The protocol (field order,
magics) mirrors serialize_fixed/serialize_planned in golden_pack.rs —
change both together.

Usage: python3 scripts/make_golden_fixtures.py [rust/tests/golden]
"""

import os
import struct
import sys

import numpy as np

M64 = (1 << 64) - 1
M128 = (1 << 128) - 1
PCG_MULT = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645

# Fixture geometry — keep in sync with rust/tests/golden_pack.rs.
ROWS, COLS, GROUP_LEN = 24, 16, 32
DATA_SEED = 0xF1B0
QUANT_SEED = 0x5EED_601D


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E37_79B9_7F4A_7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
        return (z ^ (z >> 31)) & M64


class Pcg64:
    """PCG-XSL-RR 128/64, seeded exactly like rust/src/rngs.rs."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        s0 = sm.next_u64()
        s1 = sm.next_u64()
        i0 = sm.next_u64()
        i1 = sm.next_u64()
        self.state = ((s0 << 64) | s1) & M128
        self.inc = (((i0 << 64) | i1) | 1) & M128
        self.next_u64()  # warm up, matching Pcg64::new
        self.next_u64()

    def next_u64(self):
        self.state = (self.state * PCG_MULT + self.inc) & M128
        rot = self.state >> 122  # top 6 bits: 0..63
        xored = ((self.state >> 64) ^ self.state) & M64
        return ((xored >> rot) | (xored << (64 - rot))) & M64

    def next_f32(self):
        return np.float32(self.next_u64() >> 40) * np.float32(1.0 / (1 << 24))


def rotl64(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


def with_stream(seed, stream):
    sm = SplitMix64((stream ^ rotl64(seed, 31)) & M64)
    return Pcg64((seed + sm.next_u64()) & M64)


def fixture_input():
    """next_f32() * 4 - 2, row-major, 384 values (float32 throughout)."""
    rng = Pcg64(DATA_SEED)
    return [
        rng.next_f32() * np.float32(4.0) - np.float32(2.0)
        for _ in range(ROWS * COLS)
    ]


def quantize_block(block, bits, rng):
    """quantize_block's uniform hot path (rust/src/quant.rs): integer-
    domain SR with one 64-bit draw feeding two scalars."""
    b_max = (1 << bits) - 1
    lo = block[0]
    hi = block[0]
    for v in block:
        if v < lo:
            lo = v
        if v > hi:
            hi = v
    rng_range = np.float32(hi - lo)
    codes = [0] * len(block)
    if rng_range <= 0:
        return lo, rng_range, codes
    scale = np.float32(b_max) / rng_range
    buffered = 0
    have_half = False
    for i, v in enumerate(block):
        hbar = (v - lo) * scale  # float32 in [0, B]
        fl = int(hbar)  # trunc == floor (hbar >= 0)
        frac = hbar - np.float32(fl)
        threshold = int(frac * np.float32(4294967296.0))
        if have_half:
            r = buffered & 0xFFFF_FFFF
            have_half = False
        else:
            buffered = rng.next_u64()
            r = buffered >> 32
            have_half = True
        up = 1 if r < threshold else 0
        codes[i] = min(fl + up, b_max)
    return lo, rng_range, codes


def pack(codes, bits):
    """pack_codes_slice: LSB-first, zero-padded final byte."""
    if bits == 8:
        return bytes(bytearray(codes))
    out = bytearray((len(codes) * bits + 7) // 8)
    per = 8 // bits
    mask = (1 << bits) - 1
    for i, c in enumerate(codes):
        out[i // per] |= (c & mask) << (bits * (i % per))
    return bytes(out)


def fixed_tensor(data, bits):
    """QuantEngine::quantize_seeded: per-block streams, whole-tensor pack."""
    n = len(data)
    ngroups = (n + GROUP_LEN - 1) // GROUP_LEN
    codes, zeros, ranges = [], [], []
    for g in range(ngroups):
        block = data[g * GROUP_LEN : min((g + 1) * GROUP_LEN, n)]
        rng = with_stream(QUANT_SEED, g)
        z, r, c = quantize_block(block, bits, rng)
        zeros.append(z)
        ranges.append(r)
        codes.extend(c)
    return pack(codes, bits), zeros, ranges


def planned_tensor(data, bits_list):
    """QuantEngine::quantize_planned_seeded: byte-aligned per-block pack."""
    n = len(data)
    packed = bytearray()
    zeros, ranges = [], []
    for g, b in enumerate(bits_list):
        block = data[g * GROUP_LEN : min((g + 1) * GROUP_LEN, n)]
        rng = with_stream(QUANT_SEED, g)
        z, r, c = quantize_block(block, b, rng)
        zeros.append(z)
        ranges.append(r)
        packed += pack(c, b)
    return bytes(packed), zeros, ranges


def f32_bytes(xs):
    return np.array(xs, dtype="<f4").tobytes()


def serialize_fixed(bits, packed, zeros, ranges):
    buf = bytearray(b"IEXGFIX1")
    buf += struct.pack("<IIII", ROWS, COLS, GROUP_LEN, bits)
    buf += struct.pack("<Q", len(packed))
    buf += packed
    buf += struct.pack("<Q", len(zeros))
    buf += f32_bytes(zeros)
    buf += f32_bytes(ranges)
    return bytes(buf)


def serialize_planned(bits_list, packed, zeros, ranges):
    buf = bytearray(b"IEXGPLN1")
    buf += struct.pack("<III", ROWS, COLS, GROUP_LEN)
    buf += struct.pack("<Q", len(bits_list))
    buf += bytes(bits_list)
    buf += struct.pack("<Q", len(packed))
    buf += packed
    buf += struct.pack("<Q", len(zeros))
    buf += f32_bytes(zeros)
    buf += f32_bytes(ranges)
    return bytes(buf)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "rust/tests/golden"
    os.makedirs(out_dir, exist_ok=True)
    data = fixture_input()
    nblocks = ROWS * COLS // GROUP_LEN

    fixtures = {}
    for bits in (2, 4, 8):
        packed, zeros, ranges = fixed_tensor(data, bits)
        assert len(packed) == ROWS * COLS * bits // 8
        fixtures[f"fixed_int{bits}"] = serialize_fixed(bits, packed, zeros, ranges)

    one_bit = [1] * nblocks
    packed, zeros, ranges = planned_tensor(data, one_bit)
    assert len(packed) == ROWS * COLS // 8
    fixtures["planned_int1"] = serialize_planned(one_bit, packed, zeros, ranges)

    hetero = [(1, 2, 4, 8)[g % 4] for g in range(nblocks)]
    packed, zeros, ranges = planned_tensor(data, hetero)
    assert len(packed) == 3 * (4 + 8 + 16 + 32)
    fixtures["planned_hetero"] = serialize_planned(hetero, packed, zeros, ranges)

    # Sanity: the SR codes must reconstruct each value to within one bin.
    for bits in (2, 4, 8):
        packed, zeros, ranges = fixed_tensor(data, bits)
        b_max = (1 << bits) - 1
        per = 8 // bits
        mask = (1 << bits) - 1
        for i, v in enumerate(data):
            code = (packed[i // per] >> (bits * (i % per))) & mask
            g = i // GROUP_LEN
            recon = np.float32(zeros[g]) + np.float32(ranges[g]) * np.float32(
                code
            ) / np.float32(b_max)
            step = ranges[g] / b_max if ranges[g] > 0 else 0.0
            assert abs(float(recon) - float(v)) <= float(step) * 1.001, (
                bits,
                i,
                float(v),
                float(recon),
            )

    for name, blob in sorted(fixtures.items()):
        path = os.path.join(out_dir, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"wrote {path} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
